"""Sharding rules: parameter/batch/cache PartitionSpecs for every arch.

Axis semantics (DESIGN.md §7.3):
  * ``pod``   — pure data parallelism across pods; only gradient
    all-reduce crosses it (optionally int8-compressed, distributed/compression.py).
  * ``data``  — batch sharding + FSDP: parameters and optimizer moments
    are additionally sharded over ``data`` and all-gathered on use.
  * ``model`` — tensor parallelism: attention heads, ff, vocab, expert-ff.

Rules are path-based over the parameter pytree and check divisibility:
a dimension that does not divide evenly falls back to replication for
attention heads (tiny archs like smollm-135m) and to GSPMD padding for
vocab (mamba2's 50280).
"""
from __future__ import annotations

import re

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

# --- version compat ----------------------------------------------------------
# ``jax.sharding.AxisType`` / ``jax.shard_map`` / ``make_mesh(axis_types=...)``
# only exist on newer JAX releases; these wrappers pin ONE spelling for the
# whole repo so every mesh/shard_map construction site works on either side.
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def axis_types_kwargs(n_axes: int) -> dict:
    """``{'axis_types': (AxisType.Auto,) * n}`` where the installed JAX has
    ``AxisType`` (>= 0.6), else ``{}`` (Auto is the only behaviour there)."""
    if _AXIS_TYPE is None:
        return {}
    return {"axis_types": (_AXIS_TYPE.Auto,) * n_axes}


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with explicit-Auto axis types where supported.

    Unlike raw ``jax.make_mesh`` — which silently builds the mesh over a
    SUBSET of the platform's devices whenever ``prod(axis_shapes)`` is
    smaller than ``len(jax.devices())`` (the rest of the fleet sits idle
    with no error) — the axis shapes here must account for every device
    the mesh draws from. To deliberately undersubscribe, pass the subset
    explicitly: ``devices=jax.devices()[:n]``.
    """
    want = 1
    for s in axis_shapes:
        want *= int(s)
    avail = list(devices) if devices is not None else jax.devices()
    if want != len(avail):
        source = (
            "the devices argument supplies"
            if devices is not None
            else "the platform exposes"
        )
        raise ValueError(
            f"mesh axis shapes {tuple(axis_shapes)} require {want} device(s) "
            f"but {source} {len(avail)}; pass an explicit subset "
            "(devices=jax.devices()[:n]) to build a smaller mesh"
        )
    kwargs = {} if devices is None else {"devices": devices}
    kwargs.update(axis_types_kwargs(len(axis_names)))
    try:
        return jax.make_mesh(axis_shapes, axis_names, **kwargs)
    except TypeError:  # installed make_mesh predates the axis_types kwarg
        kwargs.pop("axis_types", None)
        return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` on new JAX; ``jax.experimental.shard_map`` (with
    ``check_vma`` translated to its old name ``check_rep``) on old JAX."""
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map

    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def batch_axes(mesh):
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def _nbatch(mesh):
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n


def _div(n, mesh, axis="model"):
    return n % mesh.shape[axis] == 0


def param_specs(params, cfg: ArchConfig, mesh, fsdp: bool = True):
    """PartitionSpec pytree matching ``params`` (from lm.init_params)."""
    model_ok_heads = _div(cfg.num_heads, mesh) if cfg.num_heads else False
    model_ok_kv = _div(cfg.num_kv_heads, mesh) if cfg.num_kv_heads else False
    dax = "data" if fsdp else None

    vocab_ok = _div(cfg.vocab, mesh)  # pjit arg shardings must divide evenly

    def rule(path: str, leaf) -> P:
        nd = leaf.ndim
        # --- embeddings / head ---
        vax = "model" if vocab_ok else None
        if re.search(r"(^|/)embed$", path):
            if nd == 3:  # audio: (C, V, d)
                return P(None, vax, dax)
            return P(vax, dax)
        if re.search(r"(^|/)head$", path):
            if nd == 3:  # audio: (C, d, V)
                return P(None, dax, vax)
            return P(dax, vax)
        # --- attention ---
        if re.search(r"attn/w[q]$", path):
            return P(dax, "model" if model_ok_heads else None, None)
        if re.search(r"attn/w[kv]$", path):
            return P(dax, "model" if model_ok_kv else None, None)
        if re.search(r"attn/wo$", path):
            return P("model" if model_ok_heads else None, None, dax)
        if re.search(r"attn/(q_norm|k_norm)$", path):
            return P(None)
        # --- dense mlp ---
        if re.search(r"mlp/w[gu]$", path):
            return P(dax, "model")
        if re.search(r"mlp/wd$", path):
            return P("model", dax)
        # --- moe (stored FSDP+TP or FSDP+EP; shard_map view gathers data) ---
        if re.search(r"moe/router$", path):
            return P(None, None)
        if cfg.moe_parallel == "ep" and _div(cfg.num_experts, mesh):
            if re.search(r"moe/w[gud]$", path):
                return P("model", dax, None)
        if re.search(r"moe/w[gu]$", path):
            return P(None, dax, "model")
        if re.search(r"moe/wd$", path):
            return P(None, "model", dax)
        # --- mamba2 ---
        if re.search(r"mix/w[zx]$", path):
            return P(dax, "model")
        if re.search(r"mix/(wb|wc|wdt)$", path):
            return P(dax, None)
        if re.search(r"mix/conv_x$", path):
            return P(None, "model")
        if re.search(r"mix/conv_bias_x$", path):
            return P("model")
        if re.search(r"mix/(conv_b|conv_c|conv_bias_b|conv_bias_c)$", path):
            return P(None) if nd == 1 else P(None, None)
        if re.search(r"mix/norm_scale$", path):
            return P("model")
        if re.search(r"mix/out_proj$", path):
            return P("model", dax)
        if re.search(r"mix/(a_log|d_skip|dt_bias)$", path):
            return P(None)
        # --- norms & everything else: replicated ---
        return P(*([None] * nd))

    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}" if prefix else k) for k, v in tree.items()}
        # stacked leaves have leading layer axes; specs must be rank-matched.
        return None  # placeholder, handled below

    # flatten with paths so stacked (L, ...) leaves get a leading None axis
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        # count leading stacking axes: blocks/... and groups/... are scanned
        lead = 0
        if re.search(r"(^|/)(blocks|tail)/", pstr):
            lead = 1
        elif re.search(r"(^|/)groups/", pstr):
            lead = 2
        core = pstr
        base_spec = rule(core, _strip_lead(leaf, lead))
        spec = P(*([None] * lead + list(base_spec)))
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


class _FakeLeaf:
    def __init__(self, ndim):
        self.ndim = ndim


def _strip_lead(leaf, lead):
    return _FakeLeaf(leaf.ndim - lead)


def batch_spec(cfg: ArchConfig, mesh, global_batch: int):
    """tokens/labels (B, S[, C]) and patch_embeds (B, S, d)."""
    bspec = batch_axes(mesh) if global_batch % _nbatch(mesh) == 0 else None
    def spec_for(leaf_ndim):
        return P(*([bspec] + [None] * (leaf_ndim - 1)))
    return spec_for


def cache_specs(cache, cfg: ArchConfig, mesh, global_batch: int):
    """Decode-cache specs: batch over (pod,data) when divisible; the KV
    sequence dim over ``model`` (sequence-parallel decode attention —
    XLA completes the softmax with small (B,H) all-reduces); mamba
    d_inner/heads over ``model``."""
    bax = batch_axes(mesh) if global_batch % _nbatch(mesh) == 0 else None

    def rule(path: str, leaf):
        lead = 1  # every cache leaf is stacked over layers/groups
        if re.search(r"(^|/)groups/", path):
            lead = 2
        nd = leaf.ndim - lead
        if re.search(r"(^|/)(k|v|k_scale|v_scale)$", path):  # (B, S, KV, hd|1)
            spec = [bax, "model", None, None]
        elif re.search(r"conv_x$", path):  # (B, K-1, di)
            spec = [bax, None, "model"]
        elif re.search(r"(conv_b|conv_c)$", path):  # (B, K-1, n)
            spec = [bax, None, None]
        elif re.search(r"ssd$", path):  # (B, H, P, N)
            spec = [bax, "model" if _div(cfg.ssm_heads, mesh) else None, None, None]
        else:
            spec = [None] * nd
        return P(*([None] * lead + spec))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        specs.append(rule(pstr, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


def constrain(x, mesh, *dims):
    """with_sharding_constraint helper; no-op when mesh is None.

    ``dims`` are per-dimension axis names (or None); the batch entry
    ``"batch"`` expands to the (pod, data) tuple and is dropped when the
    dim does not divide (decode at global_batch=1)."""
    if mesh is None:
        return x
    spec = []
    for i, d in enumerate(dims):
        if d == "batch":
            bax = batch_axes(mesh)
            spec.append(bax if x.shape[i] % _nbatch(mesh) == 0 else None)
        elif d is not None and d.endswith("!"):
            # force the axis even when uneven — GSPMD pads the ragged shard
            # (e.g. 9 attention heads over 16 model shards beats replication)
            spec.append(d[:-1])
        elif d is not None and x.shape[i] % mesh.shape[d] == 0:
            spec.append(d)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec))
    )


def to_named(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
