"""Gradient compression for the cross-pod (DCN) all-reduce.

At 512+ chips the pod axis crosses data-center network, not ICI; an
int8 block-quantised all-reduce cuts that traffic 4x vs f32 (2x vs
bf16) at <1% relative error on typical gradient distributions.

Scheme: per-block (last-dim tiles of 256) absmax scaling, symmetric
int8. ``compressed_psum`` quantises, all-reduces the int8 payload and
the f32 scales separately, and dequantises — usable inside shard_map
over the ``pod`` axis. ``compress/decompress`` are exposed for the
checkpointer and tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    flat = jnp.concatenate([x.reshape(-1), jnp.zeros((pad,), x.dtype)])
    return flat.reshape(-1, BLOCK), n


def compress(x):
    """x: any-float array -> (int8 blocks, f32 scales, orig shape/count)."""
    blocks, n = _pad_to_block(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, (x.shape, n)


def decompress(q, scale, meta, dtype=jnp.float32):
    shape, n = meta
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return flat.reshape(shape).astype(dtype)


def compressed_psum(x, axis_name: str):
    """int8-quantised cross-pod gradient sum (inside shard_map over ``pod``).

    Each pod quantises locally, all-gathers the int8 payload + f32 block
    scales (wire traffic ~= 1 byte/element vs 2 for a bf16 ring
    all-reduce, 4 for f32), then dequant-sums locally. Exact-sum
    semantics up to the 1/127-per-block quantisation error
    (``quantization_error`` bounds it; tests pin < 1%)."""
    q, scale, meta = compress(x)
    qs = jax.lax.all_gather(q, axis_name)        # (g, blocks, BLOCK) int8
    ss = jax.lax.all_gather(scale, axis_name)    # (g, blocks, 1) f32
    total = jnp.sum(qs.astype(jnp.float32) * ss, axis=0)
    return total.reshape(-1)[: meta[1]].reshape(meta[0]).astype(x.dtype)


def quantization_error(x):
    """Relative L2 error of one compress/decompress round trip."""
    q, s, meta = compress(x)
    back = decompress(q, s, meta)
    num = jnp.linalg.norm((x.astype(jnp.float32) - back).reshape(-1))
    den = jnp.maximum(jnp.linalg.norm(x.astype(jnp.float32).reshape(-1)), 1e-12)
    return num / den
