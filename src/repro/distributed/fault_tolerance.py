"""Fault tolerance & elasticity for 1000+-node runs.

Three mechanisms, all exercised by tests and the train driver:

1. **Checkpoint/restart** — `repro.checkpoint` (atomic commits, auto
   resume). The deterministic data pipeline makes restarts exactly
   reproducible from (step, shard) alone.

2. **Straggler mitigation** — `StragglerMonitor` tracks per-host step
   wall-times with a robust (median + MAD) envelope; hosts breaching the
   deadline get flagged for re-dispatch (the launcher re-issues that
   host's data shard to a hot spare — on TPU pods the slow host is
   usually a failing HBM or a thermally throttled chip). The monitor is
   host-side (numpy): it must keep working when jax itself wedges.

3. **Elastic re-mesh** — `shrink_mesh` rebuilds a (data, model) mesh
   from the surviving device set (model dim preserved — TP groups are
   intra-host and die together; data dim shrinks) and
   `reshard_checkpoint_tree` re-shards a restored pytree onto it. Scale
   UP uses the same path on the grown device set.
"""
from __future__ import annotations

import time
from collections import deque

import jax
import numpy as np


class StragglerMonitor:
    def __init__(self, num_hosts: int, window: int = 32, k_mad: float = 5.0,
                 floor_s: float = 1e-3):
        self.times = [deque(maxlen=window) for _ in range(num_hosts)]
        self.k_mad = k_mad
        self.floor = floor_s
        self._tick = None

    def start_step(self):
        self._tick = time.monotonic()

    def end_step(self, host: int, wall_s: float | None = None):
        if wall_s is None:
            wall_s = time.monotonic() - self._tick
        self.times[host].append(wall_s)

    def deadline(self) -> float:
        all_t = np.concatenate([np.asarray(t) for t in self.times if t] or [[0.0]])
        if all_t.size < 4:
            return float("inf")
        med = float(np.median(all_t))
        mad = float(np.median(np.abs(all_t - med))) + 1e-9
        return max(self.floor, med + self.k_mad * mad)

    def stragglers(self) -> list[int]:
        dl = self.deadline()
        out = []
        for h, t in enumerate(self.times):
            if len(t) >= 4 and float(np.median(np.asarray(t)[-4:])) > dl:
                out.append(h)
        return out


def shrink_mesh(failed_hosts: set[int], hosts_per_pod: int, model: int,
                devices=None):
    """Rebuild the production mesh without the failed hosts' devices.

    Keeps the model (TP) dimension intact and shrinks data parallelism —
    the standard elastic policy: TP groups are co-located and fail as a
    unit, DP degree is the elastic dimension."""
    devices = list(devices if devices is not None else jax.devices())
    surviving = [
        d for i, d in enumerate(devices) if (i // hosts_per_pod) not in failed_hosts
    ]
    usable = (len(surviving) // model) * model
    if usable == 0:
        raise RuntimeError("not enough surviving devices for one model group")
    data = usable // model
    from repro.distributed import sharding

    return sharding.make_mesh((data, model), ("data", "model"),
                              devices=surviving[:usable])


def reshard_checkpoint_tree(tree, specs, new_mesh):
    """Place a restored (host-memory) pytree onto a rebuilt mesh."""
    from jax.sharding import NamedSharding

    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(new_mesh, s)),
        tree,
        specs,
        is_leaf=lambda x: not isinstance(x, (dict, tuple, list)),
    )
