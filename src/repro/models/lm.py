"""Full language model: embeddings -> stacked blocks -> norm -> head,
with ``loss_fn`` (train), ``prefill`` and ``decode_step`` (serve).

Modality frontends (assignment: stubs):
  * text  — token embedding lookup.
  * audio — musicgen: (B, S, n_codebooks) EnCodec token ids; embedding =
    sum over per-codebook tables; one head per codebook; loss averaged.
  * image — pixtral: precomputed patch embeddings (B, S, d) from the stub
    ViT frontend are added to token embeddings (token ids still drive the
    LM loss, as in interleaved VLM training).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.models import layers, mamba2, transformer


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


def init_params(key, cfg: ArchConfig):
    k_embed, k_stack, k_head = jax.random.split(key, 3)
    dt = _dt(cfg)
    scale = cfg.d_model**-0.5
    p = {}
    if cfg.modality == "audio":
        p["embed"] = (
            jax.random.normal(
                k_embed, (cfg.num_codebooks, cfg.vocab, cfg.d_model), jnp.float32
            )
            * scale
        ).astype(dt)
    else:
        p["embed"] = (
            jax.random.normal(k_embed, (cfg.vocab, cfg.d_model), jnp.float32) * scale
        ).astype(dt)
    p["stack"] = transformer.stack_init(k_stack, cfg)
    p["final_norm"] = layers.rmsnorm_init(cfg)
    if cfg.modality == "audio":
        p["head"] = (
            jax.random.normal(
                k_head, (cfg.num_codebooks, cfg.d_model, cfg.vocab), jnp.float32
            )
            * scale
        ).astype(dt)
    elif not cfg.tie_embeddings:
        p["head"] = (
            jax.random.normal(k_head, (cfg.d_model, cfg.vocab), jnp.float32) * scale
        ).astype(dt)
    return p


def embed(params, tokens, cfg: ArchConfig, patch_embeds=None, mesh=None):
    cd = jnp.dtype(cfg.compute_dtype)
    if cfg.modality == "audio":
        # tokens: (B, S, n_codebooks) — sum the per-codebook embeddings
        x = sum(
            params["embed"][c][tokens[..., c]] for c in range(cfg.num_codebooks)
        ).astype(cd)
    else:
        x = params["embed"][tokens].astype(cd)
    if cfg.modality == "image" and patch_embeds is not None:
        x = x + patch_embeds.astype(cd)
    return constrain(x, mesh, "batch", "model", None)


def unembed(params, x, cfg: ArchConfig):
    """Returns logits; audio: (B, S, C, V), else (B, S, V)."""
    cd = jnp.dtype(cfg.compute_dtype)
    if cfg.modality == "audio":
        return jnp.einsum("bsd,cdv->bscv", x, params["head"].astype(cd))
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return x @ w.astype(cd)


def forward(params, tokens, cfg: ArchConfig, *, patch_embeds=None, mesh=None):
    """Teacher-forced forward. Returns (logits, aux)."""
    s = tokens.shape[1]
    x = embed(params, tokens, cfg, patch_embeds, mesh=mesh)
    positions = jnp.arange(s)
    x, _, aux = transformer.stack_apply(params["stack"], x, positions, cfg, mesh=mesh)
    x = layers.rmsnorm_apply(params["final_norm"], x, cfg)
    logits = unembed(params, x, cfg)
    if cfg.modality == "audio":
        logits = constrain(logits, mesh, "batch", None, None, "model")
    else:
        logits = constrain(logits, mesh, "batch", None, "model")
    return logits, aux


def loss_fn(params, batch, cfg: ArchConfig, *, mesh=None, aux_weight=0.01):
    """Mean next-token cross-entropy (fp32 log-softmax) + MoE aux loss.

    The gold-logit term is a one-hot contraction, NOT take_along_axis: a
    gather along the vocab axis would force GSPMD to all-gather the
    model-sharded logits (hundreds of GiB at production shapes), while
    the compare+select+reduce fuses and keeps the vocab axis sharded.
    """
    logits, aux = forward(
        params, batch["tokens"], cfg, patch_embeds=batch.get("patch_embeds"),
        mesh=mesh,
    )
    labels = batch["labels"]
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    onehot = (vocab_iota == labels[..., None]).astype(jnp.float32)
    gold = jnp.sum(logits32 * onehot, axis=-1)
    nll = (lse - gold).mean()
    return nll + aux_weight * aux, {"nll": nll, "aux": aux}


# ============================== serving =======================================
def prefill(params, tokens, cfg: ArchConfig, *, patch_embeds=None, mesh=None):
    """Serving prefill: run the full prompt, build the KV/SSM cache, and
    return the last-position logits (next-token distribution) + cache."""
    s = tokens.shape[1]
    x = embed(params, tokens, cfg, patch_embeds, mesh=mesh)
    positions = jnp.arange(s)
    x, caches, _ = transformer.stack_apply(
        params["stack"], x, positions, cfg, mesh=mesh, collect_cache=True
    )
    x = layers.rmsnorm_apply(params["final_norm"], x[:, -1:], cfg)
    logits = unembed(params, x, cfg)
    return jnp.argmax(logits, axis=-1), logits, caches


def init_cache(cfg: ArchConfig, batch: int, seq_len: int):
    """Stacked per-layer cache pytree sized for ``seq_len``."""
    def attn_cache():
        return layers.attention_cache_init(cfg, batch, seq_len)

    def stack_leaves(n, fn):
        one = fn()
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), one)

    if cfg.family in ("dense", "moe"):
        return stack_leaves(cfg.num_layers, attn_cache)
    if cfg.family == "ssm":
        return stack_leaves(
            cfg.num_layers, lambda: mamba2.mamba_cache_init(cfg, batch)
        )
    if cfg.family == "hybrid":
        period = cfg.hybrid_period
        n_groups, tail = divmod(cfg.num_layers, period)
        mcache = lambda: mamba2.mamba_cache_init(cfg, batch)
        grp = stack_leaves(n_groups * period, mcache)
        grp = jax.tree.map(
            lambda a: a.reshape((n_groups, period) + a.shape[1:]), grp
        )
        out = {"groups": grp, "shared_attn": stack_leaves(n_groups, attn_cache)}
        if tail:
            out["tail"] = stack_leaves(tail, mcache)
        return out
    raise ValueError(cfg.family)


def decode_step(params, cache, tokens, pos, cfg: ArchConfig, *,
                patch_embeds=None, mesh=None):
    """One token for every sequence in the batch.

    tokens: (B, 1) (audio: (B, 1, C)); pos: scalar absolute position.
    Returns (next_token_ids, logits, new_cache).
    """
    x = embed(params, tokens, cfg, patch_embeds, mesh=mesh)
    positions = jnp.full((1,), pos, jnp.int32)
    x, new_cache, _ = transformer.stack_apply(
        params["stack"], x, positions, cfg, caches=cache, pos=pos, mesh=mesh
    )
    x = layers.rmsnorm_apply(params["final_norm"], x, cfg)
    logits = unembed(params, x, cfg)
    next_ids = jnp.argmax(logits, axis=-1)
    return next_ids, logits, new_cache


def param_count(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))
