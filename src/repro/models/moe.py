"""Mixture-of-Experts FFN — sorted-token ragged dispatch, TPU-adapted.

GPU MoE implementations scatter tokens through global memory (megablocks);
the TPU-native adaptation here sorts tokens by expert id *locally on each
data shard* and drives ``jax.lax.ragged_dot`` over the contiguous groups —
MXU-friendly, no (tokens, experts, capacity) one-hot dispatch tensors, and
fully dropless. Expert weights are sharded tensor-parallel on the expert
ff dimension over the ``model`` axis; the contraction is completed with a
single psum (identical collective pattern to the dense FFN, so MoE and
dense cells are directly comparable in the roofline table).

Two entry points:
  * ``moe_apply_local``  — pure-jnp, no collectives (unit tests, 1 device)
  * ``moe_apply``        — wraps the local fn in shard_map over the mesh
    (the data-shard-local sort is what makes this legal: no cross-device
    token traffic, unlike an auto-pjit argsort over a sharded axis).

Router aux loss (load balancing, Switch-style) is returned alongside.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard_map


def moe_init(key, cfg: ArchConfig):
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    nrm = lambda k, shape, s: (jax.random.normal(k, shape, jnp.float32) * s).astype(dt)
    return {
        "router": nrm(ks[0], (d, e), d**-0.5).astype(jnp.float32),
        "wg": nrm(ks[1], (e, d, ff), d**-0.5),
        "wu": nrm(ks[2], (e, d, ff), d**-0.5),
        "wd": nrm(ks[3], (e, ff, d), ff**-0.5),
    }


def moe_apply_local(params, x, cfg: ArchConfig, axis_name: str | None = None,
                    impl: str | None = None, capacity_factor: float = 1.25):
    """x: (B, S, d) shard-local. Returns (y, aux_loss).

    ``impl="ragged"`` drives jax.lax.ragged_dot over the sorted groups —
    the TPU-native path. ``impl="scan"`` (default here) scans the experts
    with a static per-expert capacity (ceil(cf * T * k / E)) and dense
    MXU panels; tokens past capacity drop (cf=1.25 keeps drops ~0 under
    the aux-balanced router). The CPU dry-run must use "scan":
    ragged_dot's CPU decomposition materialises (E, T*k, d) masks —
    observed 1 TiB+ buffers at prefill_32k on qwen3-moe.
    """
    impl = impl or cfg.moe_impl
    cd = jnp.dtype(cfg.compute_dtype)
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    xt = x.reshape(t, d).astype(cd)

    # --- routing (fp32) ---
    logits = xt.astype(jnp.float32) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, k)  # (T, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)  # renormalise over top-k

    # Switch aux loss: E * sum_e f_e * p_e  (f = token fraction, p = mean prob)
    f = jnp.zeros((e,)).at[ids.reshape(-1)].add(1.0) / (t * k)
    p_mean = probs.mean(axis=0)
    aux = e * jnp.sum(f * p_mean)

    # --- sort token-replicas by expert id ---
    flat_ids = ids.reshape(-1)  # (T*k,)
    sort_idx = jnp.argsort(flat_ids)
    token_of = sort_idx // k  # original token for each sorted slot
    xs = xt[token_of]  # (T*k, d) grouped by expert
    group_sizes = jnp.bincount(flat_ids, length=e).astype(jnp.int32)

    if impl == "ragged":
        g = jax.lax.ragged_dot(xs, params["wg"].astype(cd), group_sizes)
        u = jax.lax.ragged_dot(xs, params["wu"].astype(cd), group_sizes)
        h = jax.nn.silu(g) * u
        out = jax.lax.ragged_dot(h, params["wd"].astype(cd), group_sizes)
    elif impl == "group":
        out = _group_experts(params, xs, flat_ids, sort_idx, group_sizes, cfg,
                             capacity_factor, cd)
    else:
        out = _scan_experts(params, xs, group_sizes, cfg, capacity_factor, cd)
    if axis_name is not None:
        out = jax.lax.psum(out, axis_name)  # complete the ff contraction (TP)

    # --- unsort + gate-weighted combine ---
    gate_sorted = gate.reshape(-1)[sort_idx].astype(cd)
    y = jnp.zeros((t, d), cd).at[token_of].add(out * gate_sorted[:, None])
    return y.reshape(b, s, d), aux


def _group_experts(params, xs, flat_ids, sort_idx, group_sizes, cfg,
                   capacity_factor, cd):
    """§Perf iteration: fixed-slot capacity layout + ONE batched einsum.

    Scatter each sorted row into slot (expert*cap + rank-in-group), run
    (E, cap, d) x (E, d, ff) batched matmuls (one MXU-friendly einsum, no
    128-step scan, no dynamic-slice read-modify-write traffic), gather
    rows back. Same drop semantics as the scan impl (rank >= cap drops).
    """
    rows, d = xs.shape
    e = cfg.num_experts
    cap = int(capacity_factor * rows / e + 0.5)
    cap = max(8, -(-cap // 8) * 8)
    cap = min(cap, rows)
    sorted_ids = flat_ids[sort_idx]                      # (rows,) grouped
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(group_sizes)[:-1]]
    )
    rank = jnp.arange(rows) - starts[sorted_ids]         # rank within group
    slot = sorted_ids * cap + jnp.minimum(rank, cap - 1)
    keep = (rank < cap)[:, None]

    buf = jnp.zeros((e * cap, d), cd).at[slot].set(jnp.where(keep, xs, 0.0))
    xg = buf.reshape(e, cap, d)
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", xg, params["wg"].astype(cd))
    ) * jnp.einsum("ecd,edf->ecf", xg, params["wu"].astype(cd))
    og = jnp.einsum("ecf,efd->ecd", h, params["wd"].astype(cd))
    out = og.reshape(e * cap, d)[slot]                   # (rows, d)
    return jnp.where(keep, out, 0.0)


def _scan_experts(params, xs, group_sizes, cfg, capacity_factor, cd):
    """Static-capacity expert scan over the sorted token stream."""
    rows, d = xs.shape
    e = cfg.num_experts
    cap = int(capacity_factor * rows / e + 0.5)
    cap = max(8, -(-cap // 8) * 8)  # round up to 8
    cap = min(cap, rows)
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(group_sizes)[:-1]]
    )
    # pad so dynamic_slice(start, cap) never clamps
    xs_pad = jnp.concatenate([xs, jnp.zeros((cap, d), xs.dtype)], axis=0)
    y_pad = jnp.zeros_like(xs_pad)

    def one_expert(y_acc, inp):
        wg, wu, wd, start, size = inp
        xe = jax.lax.dynamic_slice(xs_pad, (start, 0), (cap, d))
        valid = (jnp.arange(cap) < size)[:, None]
        h = jax.nn.silu(xe @ wg.astype(cd)) * (xe @ wu.astype(cd))
        oe = h @ wd.astype(cd)
        cur = jax.lax.dynamic_slice(y_acc, (start, 0), (cap, d))
        oe = jnp.where(valid, oe, cur)  # keep neighbours outside our group
        return jax.lax.dynamic_update_slice(y_acc, oe, (start, 0)), None

    y_pad, _ = jax.lax.scan(
        one_expert, y_pad,
        (params["wg"], params["wu"], params["wd"], starts, group_sizes),
    )
    return y_pad[:rows]


def moe_apply_ep_local(params, x, cfg: ArchConfig, axis_name: str = "model"):
    """Expert-parallel shard-local body: this model shard owns experts
    [idx*E_loc, (idx+1)*E_loc) with FULL ff width; it routes the (model-
    replicated) local tokens, computes only its experts' share, and a psum
    over ``axis_name`` combines — identical FLOPs and collective volume to
    the TP layout, but expert matmuls stay MXU-wide (qwen3-moe: ff 1536
    vs 1536/16=96 under TP; see EXPERIMENTS.md §Perf B3)."""
    cd = jnp.dtype(cfg.compute_dtype)
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    e_loc = params["wg"].shape[0]  # experts owned by this shard
    t = b * s
    xt = x.reshape(t, d).astype(cd)

    logits = xt.astype(jnp.float32) @ params["router"]  # router replicated
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    f = jnp.zeros((e,)).at[ids.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(f * probs.mean(axis=0))

    offset = jax.lax.axis_index(axis_name) * e_loc
    flat_ids = ids.reshape(-1)
    local = (flat_ids >= offset) & (flat_ids < offset + e_loc)
    # sort with non-local replicas pushed to a tail bucket (id e_loc)
    local_ids = jnp.where(local, flat_ids - offset, e_loc)
    sort_idx = jnp.argsort(local_ids)
    token_of = sort_idx // k
    xs = xt[token_of]
    group_sizes = jnp.bincount(local_ids, length=e_loc + 1)[:-1].astype(jnp.int32)

    cfg_loc = dataclasses.replace(cfg, num_experts=e_loc)
    # capacity must follow the GLOBAL expert count: only ~rows*e_loc/e of
    # this shard's row stream is local (the rest sits in the tail bucket)
    out = _dispatch_sorted(params, xs, group_sizes, cfg_loc, cd,
                           capacity_factor=1.25 * e_loc / e)

    gate_sorted = jnp.where(local[sort_idx], gate.reshape(-1)[sort_idx], 0.0)
    y = jnp.zeros((t, d), cd).at[token_of].add(out * gate_sorted[:, None].astype(cd))
    y = jax.lax.psum(y, axis_name)
    return y.reshape(b, s, d), aux


def _dispatch_sorted(params, xs, group_sizes, cfg_loc, cd,
                     capacity_factor=1.25):
    """Run the configured impl on an already expert-sorted row stream
    (rows beyond sum(group_sizes) belong to other shards and produce 0)."""
    if cfg_loc.moe_impl == "group":
        rows = xs.shape[0]
        sorted_ids = jnp.clip(
            jnp.searchsorted(jnp.cumsum(group_sizes), jnp.arange(rows),
                             side="right"),
            0, cfg_loc.num_experts - 1,
        ).astype(jnp.int32)
        return _group_experts(params, xs, sorted_ids, jnp.arange(rows),
                              group_sizes, cfg_loc, capacity_factor, cd)
    return _scan_experts(params, xs, group_sizes, cfg_loc, capacity_factor, cd)


def moe_apply(params, x, cfg: ArchConfig, mesh=None):
    """Auto-sharded entry: shard_map over (pod)+data+model axes."""
    if mesh is None:
        return moe_apply_local(params, x, cfg)
    batch_axes = tuple(n for n in mesh.axis_names if n in ("pod", "data"))
    n_batch_shards = 1
    for a in batch_axes:
        n_batch_shards *= mesh.shape[a]
    # decode at global_batch=1 cannot shard the batch dim — replicate it
    shard_batch = x.shape[0] % n_batch_shards == 0
    x_spec = P(batch_axes, None, None) if shard_batch else P(None, None, None)
    ep = cfg.moe_parallel == "ep" and cfg.num_experts % mesh.shape["model"] == 0
    if ep:
        # expert parallelism: each model shard owns E/16 FULL-width experts
        wspecs = {
            "router": P(),
            "wg": P("model", None, None),
            "wu": P("model", None, None),
            "wd": P("model", None, None),
        }
    else:
        # tensor parallelism within experts (ff sharded)
        wspecs = {
            "router": P(),
            "wg": P(None, None, "model"),
            "wu": P(None, None, "model"),
            "wd": P(None, "model", None),
        }
    specs_in = (wspecs, x_spec)

    def local(prm, xloc):
        if ep:
            y, aux = moe_apply_ep_local(prm, xloc, cfg, axis_name="model")
        else:
            y, aux = moe_apply_local(prm, xloc, cfg, axis_name="model")
        if shard_batch:
            aux = jax.lax.pmean(aux, batch_axes)
        return y, aux

    y, aux = shard_map(
        local,
        mesh=mesh,
        in_specs=specs_in,
        out_specs=(x_spec, P()),
        check_vma=False,
    )(params, x)
    return y, aux
