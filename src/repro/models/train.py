"""Train-step factory: AdamW + global-norm clip + cosine schedule.

``make_train_step(cfg)`` returns a pure ``(params, opt_state, batch) ->
(params, opt_state, metrics)`` suitable for jit/pjit — this is exactly
what the multi-pod dry-run lowers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.optim import adamw
from repro.optim.adamw import apply_updates, clip_by_global_norm, cosine_schedule


def make_optimizer(cfg: ArchConfig, peak_lr=3e-4, warmup=200, total=10000):
    return adamw(
        cosine_schedule(peak_lr, warmup, total),
        b1=0.9,
        b2=0.95,
        weight_decay=0.1,
        moment_dtype=jnp.dtype(cfg.moment_dtype),
        # scan_stacked=True re-measured WORSE on the CPU dry-run backend
        # (XLA hoists the f32 converts out of the map) — see §Perf log.
        scan_stacked=False,
    )


def make_train_step(cfg: ArchConfig, mesh=None, clip_norm: float = 1.0,
                    peak_lr: float = 3e-4):
    """When ``cfg.grad_accum > 1`` the global batch is split into
    microbatches scanned sequentially with bf16 gradient accumulation —
    the remat-saved activation stack then scales with the microbatch, not
    the global batch (this is what fits llama3-405b's 1M-token step into
    16 GB HBM/chip; see EXPERIMENTS.md §Perf)."""
    opt_init, opt_update = make_optimizer(cfg, peak_lr=peak_lr)
    acc = cfg.grad_accum

    def loss_and_grad(params, batch):
        return jax.value_and_grad(
            lambda p: lm.loss_fn(p, batch, cfg, mesh=mesh), has_aux=True
        )(params)

    def train_step(params, opt_state, batch):
        if acc > 1:
            micro = jax.tree.map(
                lambda x: x.reshape((acc, x.shape[0] // acc) + x.shape[1:]), batch
            )

            def one(carry, mb):
                gsum, lsum, nsum, asum = carry
                (loss, parts), grads = loss_and_grad(params, mb)
                gsum = jax.tree.map(
                    lambda a, g: a + (g / acc).astype(a.dtype), gsum, grads
                )
                return (gsum, lsum + loss / acc, nsum + parts["nll"] / acc,
                        asum + parts["aux"] / acc), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
            (grads, loss, nll, aux), _ = jax.lax.scan(
                one, (zeros, 0.0, 0.0, 0.0), micro
            )
            parts = {"nll": nll, "aux": aux}
        else:
            (loss, parts), grads = loss_and_grad(params, batch)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt_update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": loss, "nll": parts["nll"], "aux": parts["aux"],
                   "grad_norm": gnorm}
        return params, opt_state, metrics

    return opt_init, train_step
