"""Transformer building blocks: RMSNorm, RoPE, GQA attention (qk-norm,
sliding window, KV cache), SwiGLU/GELU MLP.

Functional convention: ``<thing>_init(key, cfg) -> params`` and
``<thing>_apply(params, x, ...)``. Parameters are plain dicts; compute
dtype comes from the ArchConfig, with fp32 for norms/softmax.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.kernels import ops


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


def _normal(key, shape, dtype, scale):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# =============================== RMSNorm ======================================
def rmsnorm_init(cfg: ArchConfig, dim=None):
    return {"scale": jnp.ones((dim or cfg.d_model,), _dtype(cfg))}


def rmsnorm_apply(params, x, cfg: ArchConfig):
    return ops.rmsnorm(x, params["scale"], backend=cfg.kernel_backend)


# =============================== RoPE =========================================
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (S,) or (B, S) absolute positions."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    if positions.ndim == 1:
        angles = positions[:, None].astype(jnp.float32) * freqs[None]  # (S, D/2)
        angles = angles[None, :, None, :]  # (1, S, 1, D/2)
    else:
        angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
        angles = angles[:, :, None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# =============================== Attention ====================================
def attention_init(key, cfg: ArchConfig):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "wq": _normal(ks[0], (d, h, hd), dt, scale),
        "wk": _normal(ks[1], (d, kv, hd), dt, scale),
        "wv": _normal(ks[2], (d, kv, hd), dt, scale),
        "wo": _normal(ks[3], (h, hd, d), dt, (h * hd) ** -0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def attention_apply(params, x, positions, cfg: ArchConfig, *, cache=None, pos=None,
                    collect_kv=False, mesh=None):
    """x: (B, S, d). Returns (out, new_cache).

    Prefill/train: cache=None, positions (S,); ``collect_kv`` additionally
    returns the K/V cache (prefill serving path — the write-out bytes are
    part of the prefill roofline).
    Decode: S==1; cache={"k","v"}: (B, S_max, KV, hd); pos scalar write index.
    """
    cd = jnp.dtype(cfg.compute_dtype)
    x = x.astype(cd)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(cd))
    if cfg.cp_attention:
        # context parallelism: queries sharded along S, K/V gathered once —
        # avoids the S->head all-to-all reshard inside the q-chunk scan
        q = constrain(q, mesh, "batch", "model", None, None)
    else:
        q = constrain(q, mesh, "batch", None, "model!", None)
    k = constrain(k, mesh, "batch", None, None, None)
    v = constrain(v, mesh, "batch", None, None, None)

    if cfg.qk_norm:
        q = ops.rmsnorm(q, params["q_norm"], backend=cfg.kernel_backend)
        k = ops.rmsnorm(k, params["k_norm"], backend=cfg.kernel_backend)

    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = ops.attention(
            q, k, v, causal=True, window=cfg.window, backend=cfg.kernel_backend
        )
        new_cache = None
        if collect_kv:
            keep = min(k.shape[1], cfg.window) if cfg.window > 0 else k.shape[1]
            new_cache = {
                "k": constrain(k[:, -keep:], mesh, "batch", "model", None, None),
                "v": constrain(v[:, -keep:], mesh, "batch", "model", None, None),
            }
    else:
        # write the new K/V at slot `pos` (ring-buffer slot for SWA).
        # Keep everything in the cache's layout (batch, S->model) — decode
        # attention is sequence-parallel (partial softmax + tiny all-reduce);
        # without these constraints GSPMD reshards the cache to kv-head
        # sharding every layer (full rematerialisation, see EXPERIMENTS).
        slot = pos % cache["k"].shape[1] if cfg.window > 0 else pos
        int8_kv = cfg.kv_cache_dtype == "int8"
        if int8_kv:
            # §Perf: per-(token, head) absmax int8 — halves the KV stream,
            # the decode-cell HBM floor
            kq, ks_ = _quant_kv(k)
            vq, vs_ = _quant_kv(v)
            new_cache = {
                "k": _dus(cache["k"], kq, slot),
                "v": _dus(cache["v"], vq, slot),
                "k_scale": _dus(cache["k_scale"], ks_, slot),
                "v_scale": _dus(cache["v_scale"], vs_, slot),
            }
            new_cache = {
                kk: constrain(vv, mesh, "batch", "model", None, None)
                for kk, vv in new_cache.items()
            }
            ck = new_cache["k"].astype(cd) * new_cache["k_scale"].astype(cd)
            cv = new_cache["v"].astype(cd) * new_cache["v_scale"].astype(cd)
        else:
            ck = _dus(cache["k"], k.astype(cache["k"].dtype), slot)
            cv = _dus(cache["v"], v.astype(cache["v"].dtype), slot)
            ck = constrain(ck, mesh, "batch", "model", None, None)
            cv = constrain(cv, mesh, "batch", "model", None, None)
            new_cache = {"k": ck, "v": cv}
        q = constrain(q, mesh, "batch", None, None, None)
        if cfg.window > 0:
            # ring cache: while cold (pos < window) only slots <= pos exist;
            # once warm every slot is in-window by construction.
            pos_eff = jnp.minimum(pos, cache["k"].shape[1] - 1)
        else:
            pos_eff = pos
        out = ops.decode_attention(
            q, ck.astype(cd), cv.astype(cd), pos_eff, backend=cfg.kernel_backend
        )

    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(cd))
    if cfg.sp_block_outputs and cache is None:
        # S-shard the partial-sum output BEFORE the residual add so the
        # head-contraction lowers to reduce-scatter, not all-reduce+slice
        out = constrain(out, mesh, "batch", "model", None)
    return out, new_cache


def _dus(buf, val, slot):
    return jax.lax.dynamic_update_slice_in_dim(buf, val, slot, axis=1)


def _quant_kv(x):
    """(B, 1, KV, hd) -> int8 values + bf16 per-(token, head) scales."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def attention_cache_init(cfg: ArchConfig, batch: int, seq_len: int, dtype=None):
    s = min(seq_len, cfg.window) if cfg.window > 0 else seq_len
    shape = (batch, s, cfg.num_kv_heads, cfg.head_dim)
    if cfg.kv_cache_dtype == "int8":
        sshape = (batch, s, cfg.num_kv_heads, 1)
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(sshape, jnp.bfloat16),
            "v_scale": jnp.zeros(sshape, jnp.bfloat16),
        }
    dt = dtype or jnp.dtype(cfg.compute_dtype)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


# =============================== MLP ==========================================
def mlp_init(key, cfg: ArchConfig):
    d, ff, dt = cfg.d_model, cfg.d_ff, _dtype(cfg)
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "wg": _normal(ks[0], (d, ff), dt, d**-0.5),
            "wu": _normal(ks[1], (d, ff), dt, d**-0.5),
            "wd": _normal(ks[2], (ff, d), dt, ff**-0.5),
        }
    return {
        "wu": _normal(ks[0], (d, ff), dt, d**-0.5),
        "wd": _normal(ks[1], (ff, d), dt, ff**-0.5),
    }


def mlp_apply(params, x, cfg: ArchConfig, mesh=None):
    cd = jnp.dtype(cfg.compute_dtype)
    x = x.astype(cd)
    if cfg.mlp_type == "swiglu":
        g = x @ params["wg"].astype(cd)
        u = x @ params["wu"].astype(cd)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(x @ params["wu"].astype(cd))
    h = constrain(h, mesh, "batch", None, "model")
    out = h @ params["wd"].astype(cd)
    if cfg.sp_block_outputs:
        out = constrain(out, mesh, "batch", "model", None)
    return out
