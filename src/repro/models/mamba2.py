"""Mamba2 block (SSD — state-space duality, arXiv:2405.21060).

Layer = projections -> causal depthwise conv (x, B, C streams) -> SSD ->
gated RMSNorm -> out_proj. The SSD core routes through
``kernels.ops.ssd`` (Pallas chunked kernel on TPU / chunked-scan XLA
fallback). Decode carries a (conv_state, ssd_state) cache — O(1) per
token, which is why the ssm/hybrid archs are assigned the 500k decode.

TPU-sharding note: the reference CUDA implementation fuses one in_proj
of width 2*d_inner + 2*d_state + n_heads; we keep separate weights per
stream so the d_inner dimension shards cleanly on the ``model`` mesh axis
(the fused layout slices across shard boundaries). XLA fuses the matmuls
back together at compile time, so this costs nothing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.kernels import ops


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


def mamba_init(key, cfg: ArchConfig):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    h, k = cfg.ssm_heads, cfg.ssm_conv
    ks = jax.random.split(key, 11)
    dt = _dt(cfg)
    nrm = lambda kk, shape, s: (jax.random.normal(kk, shape, jnp.float32) * s).astype(dt)
    return {
        "wz": nrm(ks[0], (d, di), d**-0.5),
        "wx": nrm(ks[1], (d, di), d**-0.5),
        "wb": nrm(ks[2], (d, n), d**-0.5),
        "wc": nrm(ks[3], (d, n), d**-0.5),
        "wdt": nrm(ks[4], (d, h), d**-0.5),
        "conv_x": nrm(ks[5], (k, di), 0.5),
        "conv_b": nrm(ks[6], (k, n), 0.5),
        "conv_c": nrm(ks[7], (k, n), 0.5),
        "conv_bias_x": jnp.zeros((di,), dt),
        "conv_bias_b": jnp.zeros((n,), dt),
        "conv_bias_c": jnp.zeros((n,), dt),
        "a_log": jnp.log(
            jax.random.uniform(ks[8], (h,), jnp.float32, minval=1.0, maxval=16.0)
        ),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(
            jnp.exp(
                jax.random.uniform(ks[9], (h,), jnp.float32, minval=1e-3, maxval=0.1)
            )
            - 1.0
        ),
        "norm_scale": jnp.ones((di,), dt),
        "out_proj": nrm(ks[10], (di, d), di**-0.5),
    }


def _causal_conv(x, w, bias, cache=None):
    """Depthwise causal conv. x: (B, S, C); w: (K, C); cache: (B, K-1, C)."""
    k = w.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    else:
        pad = cache.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, C)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    new_cache = xp[:, -(k - 1) :, :]
    return out + bias[None, None, :], new_cache


def mamba_apply(params, x_in, cfg: ArchConfig, *, cache=None, collect_state=False,
                mesh=None):
    """x_in: (B, S, d). cache: {"conv_x","conv_b","conv_c","ssd"} or None.
    Returns (out (B, S, d), new_cache)."""
    cd = jnp.dtype(cfg.compute_dtype)
    bsz, s, _ = x_in.shape
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    x_in = x_in.astype(cd)

    z = constrain(x_in @ params["wz"].astype(cd), mesh, "batch", None, "model")
    xs = constrain(x_in @ params["wx"].astype(cd), mesh, "batch", None, "model")
    b = x_in @ params["wb"].astype(cd)
    c = x_in @ params["wc"].astype(cd)
    dt_raw = x_in @ params["wdt"].astype(cd)

    cx = None if cache is None else cache["conv_x"]
    cb = None if cache is None else cache["conv_b"]
    cc = None if cache is None else cache["conv_c"]
    xs, ncx = _causal_conv(xs, params["conv_x"].astype(cd),
                           params["conv_bias_x"].astype(cd), cache=cx)
    b, ncb = _causal_conv(b, params["conv_b"].astype(cd),
                          params["conv_bias_b"].astype(cd), cache=cb)
    c, ncc = _causal_conv(c, params["conv_c"].astype(cd),
                          params["conv_bias_c"].astype(cd), cache=cc)
    xs = jax.nn.silu(xs).reshape(bsz, s, h, p)
    xs = constrain(xs, mesh, "batch", None, "model", None)
    b = jax.nn.silu(b)
    c = jax.nn.silu(c)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"][None, None, :]
    )  # (B, S, H)

    if cache is None:
        y, _state = ops.ssd(
            xs, dt, params["a_log"], b, c, params["d_skip"],
            chunk=cfg.ssm_chunk, backend=cfg.kernel_backend,
        )
        new_cache = None
        if collect_state:
            new_cache = {"conv_x": ncx, "conv_b": ncb, "conv_c": ncc, "ssd": _state}
    else:
        y, state = ops.ssd_decode(
            cache["ssd"], xs[:, 0], dt[:, 0], params["a_log"], b[:, 0], c[:, 0],
            params["d_skip"],
        )
        y = y[:, None]  # (B, 1, H, P)
        new_cache = {"conv_x": ncx, "conv_b": ncb, "conv_c": ncc, "ssd": state}

    y = y.reshape(bsz, s, cfg.d_inner)
    # gated RMSNorm (mamba2: norm(y * silu(z)))
    y = y * jax.nn.silu(z)
    y32 = y.astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(jnp.square(y32), axis=-1, keepdims=True) + 1e-6)
    y = ((y32 / rms) * params["norm_scale"].astype(jnp.float32)).astype(cd)
    return y @ params["out_proj"].astype(cd), new_cache


def mamba_cache_init(cfg: ArchConfig, batch: int, dtype=None):
    dt = dtype or jnp.dtype(cfg.compute_dtype)
    k = cfg.ssm_conv - 1
    return {
        "conv_x": jnp.zeros((batch, k, cfg.d_inner), dt),
        "conv_b": jnp.zeros((batch, k, cfg.ssm_state), dt),
        "conv_c": jnp.zeros((batch, k, cfg.ssm_state), dt),
        "ssd": jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        ),
    }
