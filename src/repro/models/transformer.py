"""Decoder blocks + scanned stacks for all four families.

All stacks scan over stacked per-layer parameter pytrees — compile time
is O(1) in depth (126-layer models lower in seconds) and remat applies
per block. The hybrid (zamba2) stack scans super-blocks of
``hybrid_period`` mamba layers followed by ONE shared attention block
(weights reused across every application, as in the paper).

Three modes through one code path:
  * train:    caches=None, collect_cache=False -> (x, None, aux)
  * prefill:  caches=None, collect_cache=True  -> (x, stacked caches, aux)
  * decode:   caches=pytree (S==1, pos set)    -> (x, updated caches, aux)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers, mamba2, moe


# ============================ single blocks ===================================
def dense_block_init(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": layers.rmsnorm_init(cfg),
        "attn": layers.attention_init(k1, cfg),
        "ln2": layers.rmsnorm_init(cfg),
    }
    if cfg.is_moe:
        p["moe"] = moe.moe_init(k2, cfg)
    else:
        p["mlp"] = layers.mlp_init(k2, cfg)
    return p


def dense_block_apply(params, x, positions, cfg: ArchConfig, *, cache=None,
                      pos=None, mesh=None, collect_cache=False):
    """Returns (x, new_cache, aux)."""
    from repro.distributed.sharding import constrain

    x = constrain(x, mesh, "batch", "model", None)
    h, new_cache = layers.attention_apply(
        params["attn"],
        layers.rmsnorm_apply(params["ln1"], x, cfg),
        positions,
        cfg,
        cache=cache,
        pos=pos,
        collect_kv=collect_cache,
        mesh=mesh,
    )
    x = x + h
    normed = layers.rmsnorm_apply(params["ln2"], x, cfg)
    if cfg.is_moe:
        f, aux = moe.moe_apply(params["moe"], normed, cfg, mesh=mesh)
    else:
        f, aux = layers.mlp_apply(params["mlp"], normed, cfg, mesh=mesh), jnp.float32(0)
    return constrain(x + f, mesh, "batch", "model", None), new_cache, aux


def mamba_block_init(key, cfg: ArchConfig):
    return {"ln": layers.rmsnorm_init(cfg), "mix": mamba2.mamba_init(key, cfg)}


def mamba_block_apply(params, x, cfg: ArchConfig, *, cache=None,
                      collect_cache=False, mesh=None):
    from repro.distributed.sharding import constrain

    x = constrain(x, mesh, "batch", "model", None)
    h, new_cache = mamba2.mamba_apply(
        params["mix"], layers.rmsnorm_apply(params["ln"], x, cfg), cfg,
        cache=cache, collect_state=collect_cache, mesh=mesh,
    )
    return constrain(x + h, mesh, "batch", "model", None), new_cache


# ============================ stacks ==========================================
def _stack_init(key, n, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _maybe_remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn

    # optimization_barrier on the carry AND the sliced xs: without it XLA
    # hoists the body's bf16->f32 converts out of the loop and materialises
    # f32 copies of the whole activation stack / KV cache / layer weights
    # (observed 31.5 GiB extra on llama3-405b train, 7.9 GiB on decode).
    def barriered(carry, xs):
        carry = jax.lax.optimization_barrier(carry)
        if xs is not None:
            xs = jax.lax.optimization_barrier(xs)
        return fn(carry, xs)

    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(barriered, policy=policy)
    return jax.checkpoint(barriered)  # "full": save nothing


def stack_init(key, cfg: ArchConfig):
    if cfg.family in ("dense", "moe"):
        return {"blocks": _stack_init(key, cfg.num_layers,
                                      lambda k: dense_block_init(k, cfg))}
    if cfg.family == "ssm":
        return {"blocks": _stack_init(key, cfg.num_layers,
                                      lambda k: mamba_block_init(k, cfg))}
    if cfg.family == "hybrid":
        period = cfg.hybrid_period
        n_groups, tail = divmod(cfg.num_layers, period)
        k1, k2, k3 = jax.random.split(key, 3)
        grouped = _stack_init(k1, n_groups * period,
                              lambda k: mamba_block_init(k, cfg))
        grouped = jax.tree.map(
            lambda a: a.reshape((n_groups, period) + a.shape[1:]), grouped
        )
        out = {"groups": grouped, "shared_attn": dense_block_init(k2, cfg)}
        if tail:
            out["tail"] = _stack_init(k3, tail, lambda k: mamba_block_init(k, cfg))
        return out
    raise ValueError(cfg.family)


def stack_apply(params, x, positions, cfg: ArchConfig, *, caches=None, pos=None,
                mesh=None, collect_cache=False):
    """Returns (x, new_caches_or_None, aux_sum)."""
    decode = caches is not None
    with_cache = decode or collect_cache

    if cfg.family in ("dense", "moe"):
        def body(carry, xs):
            prm, cache = xs if decode else (xs, None)
            xx, nc, aux = dense_block_apply(
                prm, carry, positions, cfg, cache=cache, pos=pos, mesh=mesh,
                collect_cache=collect_cache,
            )
            return xx, ((nc, aux) if with_cache else aux)

        body = _maybe_remat(body, cfg)
        xs = (params["blocks"], caches) if decode else params["blocks"]
        x, out = jax.lax.scan(body, x, xs)
        if with_cache:
            return x, out[0], jnp.sum(out[1])
        return x, None, jnp.sum(out)

    if cfg.family == "ssm":
        def body(carry, xs):
            prm, cache = xs if decode else (xs, None)
            xx, nc = mamba_block_apply(
                prm, carry, cfg, cache=cache, collect_cache=collect_cache, mesh=mesh
            )
            return xx, (nc if with_cache else jnp.float32(0))

        body = _maybe_remat(body, cfg)
        xs = (params["blocks"], caches) if decode else params["blocks"]
        x, out = jax.lax.scan(body, x, xs)
        return x, (out if with_cache else None), jnp.float32(0)

    if cfg.family == "hybrid":
        return _hybrid_apply(params, x, positions, cfg, caches=caches, pos=pos,
                             mesh=mesh, collect_cache=collect_cache)
    raise ValueError(cfg.family)


def _hybrid_apply(params, x, positions, cfg: ArchConfig, *, caches=None, pos=None,
                  mesh=None, collect_cache=False):
    """Zamba2: scan over super-blocks (period mamba layers + shared attn)."""
    decode = caches is not None
    with_cache = decode or collect_cache
    shared = params["shared_attn"]

    def superblock(carry, xs):
        xx = carry
        if decode:
            grp_prm, grp_cache, attn_cache = xs
        else:
            grp_prm, grp_cache, attn_cache = xs, None, None

        def inner(c, ys):
            prm, cache = ys if decode else (ys, None)
            c, nc = mamba_block_apply(
                prm, c, cfg, cache=cache, collect_cache=collect_cache, mesh=mesh
            )
            return c, (nc if with_cache else jnp.float32(0))

        xx, new_grp = jax.lax.scan(
            inner, xx, (grp_prm, grp_cache) if decode else grp_prm
        )
        xx, new_attn, _ = dense_block_apply(
            shared, xx, positions, cfg, cache=attn_cache, pos=pos, mesh=mesh,
            collect_cache=collect_cache,
        )
        return xx, ((new_grp, new_attn) if with_cache else jnp.float32(0))

    superblock = _maybe_remat(superblock, cfg)
    if decode:
        xs = (params["groups"], caches["groups"], caches["shared_attn"])
    else:
        xs = params["groups"]
    x, out = jax.lax.scan(superblock, x, xs)
    new_caches = {"groups": out[0], "shared_attn": out[1]} if with_cache else None

    if "tail" in params:
        def tail_body(c, ys):
            prm, cache = ys if decode else (ys, None)
            c, nc = mamba_block_apply(
                prm, c, cfg, cache=cache, collect_cache=collect_cache, mesh=mesh
            )
            return c, (nc if with_cache else jnp.float32(0))

        tail_body = _maybe_remat(tail_body, cfg)
        xs = (params["tail"], caches["tail"]) if decode else params["tail"]
        x, tail_out = jax.lax.scan(tail_body, x, xs)
        if with_cache:
            new_caches["tail"] = tail_out
    return x, new_caches, jnp.float32(0)
