"""Serving driver: model-aware edge serving of the AIGC model zoo.

Wires the paper's scheduling layer to the real model plane:
  * a fleet of ``EdgeServer``s (device groups), each caching a subset of
    the catalogue (the 10 assigned architectures);
  * the WHOLE request batch routed in one jitted ``core.batch_router``
    call pricing the paper's eq. 5/7/9 cost terms (transmission, model
    switch, FIFO-shared compute) with sequential-commit semantics;
  * actual prefill+decode of the routed batch through ``models.lm`` on
    the local device (reduced configs on CPU).

Workload (the scenario subsystem, ``repro.workloads``):
  * ``--scenario NAME`` picks a registered traffic shape (``steady``,
    ``bursty``, ``diurnal``, ``flash-crowd``, ``popularity-drift``,
    ``hotspot-cell`` — see ``docs/scenarios.md``); the whole stream
    (arrival stamps, model popularity, cells, prompt sizes) is compiled
    from ``(ScenarioSpec, --seed)`` by ``workloads.compile_scenario``,
    so serve runs are reproducible end to end.
  * ``--arrival-rate R`` overrides the scenario's base rate (req/s
    fleet-wide); ``--seed`` reseeds the stream.

Cell / drain knobs (the multi-cell + time-based-drain serving path):
  * ``--cells C`` partitions the fleet into C edge cells of
    ``--servers`` servers each, plus ONE cloud-fallback server
    (``make_cloud_server``) in the reserved ``CLOUD_CELL`` that every
    request can reach at backhaul-folded uplink pricing. Requests carry
    the scenario's cell column and the whole C-cell fleet is still
    routed in a single jitted call (block-diagonal score mask).
  * ``--drain-rate R`` gives every edge server R tokens/sec of
    continuous queue drain; queue decay then tracks the scenario's
    wall-clock arrival stamps inside the scan carry rather than request
    count. ``--drain-rate 0`` (default) keeps the legacy synchronous
    drain.

Policies (``--policy``, dispatched through ``core.batch_router``'s
policy contract — a traceable callable evaluated once per request inside
the routing scan; see that module's docstring for what a policy callable
receives and returns):
  * ``greedy`` (default) — argmin of the eq. 11 latency;
  * ``load``   — least-loaded server (switch-blind baseline);
  * ``drain``  — drain-aware greedy: queue backlog discounted by each
    server's ``drain_rate`` before the eq. 9 pricing, so fast-draining
    servers keep winning under bursty arrivals;
  * ``actor:<ckpt_dir>`` — a trained MADDPG-MATO actor restored from a
    ``core.policies.save_actor_checkpoint`` directory. The policy
    rebuilds the env's eq. 16 observation from live fleet state per
    request (``core.policies``); an actor trained at ``num_cells=1``
    with N servers serves every cell of a ``--cells C --servers N``
    fleet unchanged. ``benchmarks/policy_serving.py`` trains and saves
    such a checkpoint under ``benchmarks/results/actor_ckpt``.

Performance knobs (the chunked two-phase commit, see
``core.batch_router``): ``--chunk C`` scores C requests per fused
kernel call and runs the slimmed correction scan between calls
(identical routing decisions, ~2x req/s at fleet scale); ``--backend``
picks the scoring backend (``xla`` | ``pallas`` | ``pallas-interpret``,
default from ``$REPRO_ROUTER_BACKEND``).

    python -m repro.launch.serve --requests 64 --servers 3
    python -m repro.launch.serve --requests 256 --servers 4 --cells 4 \
        --drain-rate 50 --arrival-rate 100 --no-execute
    python -m repro.launch.serve --requests 1024 --servers 3 --cells 2 \
        --scenario popularity-drift --seed 7 --drain-rate 20000 --no-execute
    python -m repro.launch.serve --requests 256 --servers 3 --cells 2 \
        --drain-rate 20000 --policy drain --no-execute
    python -m repro.launch.serve --requests 256 --servers 3 --cells 2 \
        --policy actor:benchmarks/results/actor_ckpt --no-execute
    python -m repro.launch.serve --requests 4096 --servers 64 \
        --chunk 256 --no-execute
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, list_archs, reduced
from repro.core import batch_router, policies
from repro.core.catalog import build_catalog
from repro.core.router import CLOUD_CELL, EdgeServer
from repro.models import lm
from repro.workloads import compile_scenario, get_scenario, list_scenarios


def make_fleet(n_servers: int, catalog, flops=197e12, slots=2, cell=0,
               drain_rate=0.0):
    """One cell of ``n_servers`` edge servers with staggered residencies."""
    return [
        EdgeServer(
            name=f"c{cell}-es{i}", flops_per_s=flops, cache_slots=slots,
            uplink_bps=100e6, backhaul_bps=1e9,
            resident=[(2 * i + j) % len(catalog) for j in range(slots)],
            cell=cell, drain_rate=drain_rate,
        )
        for i in range(n_servers)
    ]


def make_cloud_server(catalog, flops=2e15, uplink_bps=100e6,
                      backhaul_bps=1e9, drain_rate=0.0):
    """Cloud-fallback column: every model resident, visible fleet-wide.

    The cloud sits behind the backhaul, so its effective uplink folds the
    extra hop: 1/u_eff = 1/uplink + 1/backhaul (prompt bits traverse
    both links in series). With all models resident it never pays the
    eq. 7 switch, but the slower path + shared queue keep it a fallback
    rather than a free lunch."""
    u_eff = 1.0 / (1.0 / uplink_bps + 1.0 / backhaul_bps)
    return EdgeServer(
        name="cloud", flops_per_s=flops, cache_slots=len(catalog),
        uplink_bps=u_eff, backhaul_bps=backhaul_bps,
        resident=list(range(len(catalog))),
        cell=CLOUD_CELL, drain_rate=drain_rate,
    )


def make_multicell_fleet(n_cells: int, servers_per_cell: int, catalog,
                         flops=197e12, slots=2, drain_rate=0.0,
                         cloud=True):
    """C cells x N servers (+ one cloud fallback), one flat server list."""
    fleet = []
    for c in range(n_cells):
        fleet.extend(
            make_fleet(servers_per_cell, catalog, flops=flops, slots=slots,
                       cell=c, drain_rate=drain_rate)
        )
    if cloud:
        fleet.append(make_cloud_server(catalog, drain_rate=drain_rate))
    return fleet


def resolve_policy_flag(policy, fleet_params, *, sharded=False):
    """CLI policy flag -> ``route_batch`` policy. ``actor:<ckpt_dir>``
    restores a trained MADDPG-MATO actor through ``core.policies``;
    everything else passes through (builtin name or callable).

    ``sharded=True`` builds the actor against the cell-block-local
    geometry (``policies.actor_policy_for_cell_blocks``) so the one
    closure serves every shard of ``route_batch_sharded``.

    Checkpoint problems surface as a clean ``SystemExit`` (missing dir,
    no committed step, corrupt manifest/arrays, wrong checkpoint kind)
    instead of a traceback from deep inside the restore path."""
    if isinstance(policy, str) and policy.startswith("actor:"):
        ckpt = policy.split(":", 1)[1]
        if not ckpt:
            raise SystemExit(
                "serve: --policy actor: needs a checkpoint directory, e.g. "
                "--policy actor:benchmarks/results/actor_ckpt"
            )
        try:
            if not sharded:
                return policies.load_actor_policy(ckpt, fleet_params)
            params, spec, extra = policies.load_actor_checkpoint(ckpt)
            return policies.actor_policy_for_cell_blocks(
                params, spec, fleet_params,
                model_aware=extra.get("model_aware", True),
            )
        except (FileNotFoundError, NotADirectoryError) as e:
            raise SystemExit(
                f"serve: no actor checkpoint at {ckpt!r}: {e}\n"
                "train one with benchmarks/policy_serving.py (it saves "
                "under benchmarks/results/actor_ckpt)"
            ) from e
        except (ValueError, KeyError, OSError, TypeError) as e:
            raise SystemExit(
                f"serve: could not restore actor checkpoint {ckpt!r}: "
                f"{type(e).__name__}: {e}\n"
                "the directory exists but is not a readable "
                "core.policies.save_actor_checkpoint layout "
                "(step_<N>/manifest.json + committed arrays)"
            ) from e
    return policy


def validate_mesh_flag(mesh):
    """Fail fast — BEFORE any tracing — when ``--mesh D`` asks for more
    devices than this process can see. ``jax.Mesh`` would reject the
    device array anyway, but only after the fleet/stream setup work, and
    with a shape error that doesn't mention the XLA_FLAGS escape hatch."""
    if mesh is None:
        return
    avail = jax.local_device_count()
    if mesh < 1 or mesh > avail:
        raise SystemExit(
            f"serve: --mesh {mesh} needs {mesh} local devices but only "
            f"{avail} are available; on CPU hosts expose more via "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8"
        )


def serve(num_requests=32, n_servers=3, policy="greedy", execute=True, seed=0,
          gen_tokens=8, n_cells=1, drain_rate=0.0, arrival_rate=None,
          chunk=None, backend=None, scenario="steady", mesh=None):
    validate_mesh_flag(mesh)
    # serve the edge-suitable (small) members of the catalogue
    edge_archs = ["smollm_135m", "starcoder2_3b", "mamba2_2p7b", "musicgen_medium"]
    catalog = build_catalog(edge_archs)
    multicell = n_cells > 1
    if multicell:
        fleet = make_multicell_fleet(n_cells, n_servers, catalog,
                                     drain_rate=drain_rate)
    else:
        fleet = make_fleet(n_servers, catalog, drain_rate=drain_rate)
    fleet_params, fleet_state = batch_router.fleet_from_servers(fleet, catalog)
    policy = resolve_policy_flag(policy, fleet_params, sharded=mesh is not None)

    # local reduced models actually generate tokens for routed requests
    models = {}
    if execute:
        for e in catalog:
            cfg = reduced(get_arch(e.name))
            models[e.index] = (cfg, lm.init_params(jax.random.key(e.index), cfg))

    # the whole stream — arrival stamps, model popularity, cells, prompt
    # sizes — compiles from (ScenarioSpec, seed): reproducible end to end
    spec = get_scenario(scenario, num_requests=num_requests)
    if arrival_rate is not None:
        spec = spec._replace(rate=arrival_rate)
    if gen_tokens is not None:  # None: keep the scenario's length range
        spec = spec._replace(gen_tokens=(gen_tokens, gen_tokens))
    reqs = compile_scenario(spec, seed=seed, num_models=len(catalog),
                            num_cells=n_cells)

    # route the WHOLE batch (all cells) in one jitted call
    # (sequential-commit scan). With drain_rate > 0 the queues decay by
    # drain_rate * dt between arrivals; otherwise each routed request
    # drains the fleet like the old per-request loop. Under --mesh the
    # batch is ONE reconciliation window of the sharded router, which
    # takes no per-request drain_tokens (docs/sharding.md) — drain only
    # through drain_rate there.
    t0 = time.time()
    if mesh is not None:
        from repro.core import mesh_router

        fleet_state, out = mesh_router.route_batch_sharded(
            fleet_params, fleet_state, reqs, num_devices=mesh,
            policy=policy, chunk=chunk, backend=backend,
        )
    else:
        fleet_state, out = batch_router.route_batch(
            fleet_params, fleet_state, reqs,
            None if drain_rate > 0.0
            else float(np.mean(np.asarray(reqs.gen_tokens))) * len(fleet)
            / max(num_requests, 1),
            policy=policy, chunk=chunk, backend=backend,
        )
    jax.block_until_ready(out.choice)
    route_s = time.time() - t0

    if execute:
        gen_counts = np.asarray(reqs.gen_tokens).astype(int)
        for model_idx, n_gen in zip(np.asarray(reqs.model), gen_counts):
            cfg, params = models[int(model_idx)]
            n_gen = int(n_gen)
            B, P = 1, 8
            if cfg.modality == "audio":
                prompt = jnp.zeros((B, P, cfg.num_codebooks), jnp.int32)
            else:
                prompt = jnp.zeros((B, P), jnp.int32)
            ids, _, cache = lm.prefill(params, prompt, cfg)
            # token-by-token generation against a fresh full cache
            full = lm.init_cache(cfg, B, P + n_gen)

            def seat(dst, src):
                if src.shape == dst.shape:
                    return src.astype(dst.dtype)
                pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
                return jnp.pad(src, pad).astype(dst.dtype)

            cache = jax.tree.map(seat, full, cache)
            tok = ids[:, -1:]
            for t in range(n_gen):
                tok, _, cache = lm.decode_step(
                    params, cache, tok, jnp.int32(P + t), cfg
                )

    # the cloud column is appended last when the fleet is multicell
    stats = batch_router.stats(
        out, cloud_index=len(fleet) - 1 if multicell else None
    )
    stats["route_s"] = route_s
    stats["wall_s"] = time.time() - t0
    stats["requests"] = num_requests
    stats["cells"] = n_cells
    stats["servers"] = len(fleet)
    stats["scenario"] = spec.name
    stats["seed"] = seed
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--servers", type=int, default=3,
                    help="edge servers per cell")
    ap.add_argument("--cells", type=int, default=1,
                    help=">1 adds a block-diagonal cell mask + cloud column")
    ap.add_argument("--drain-rate", type=float, default=0.0,
                    help="tokens/sec continuous queue drain (0 = legacy "
                         "synchronous per-request drain)")
    ap.add_argument("--scenario", default="steady", choices=list_scenarios(),
                    help="registered workload shape compiled into the "
                         "request stream (see docs/scenarios.md)")
    ap.add_argument("--seed", type=int, default=0,
                    help="stream seed: the same (scenario, seed) "
                         "regenerates the stream bit-identically")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="override the scenario's base arrival rate "
                         "(req/s fleet-wide)")
    ap.add_argument("--gen-tokens", type=int, default=8,
                    help="constant generation length (default 8, matching "
                         "the Python API); pass 0 to serve the scenario's "
                         "[lo, hi) length range instead (execute time "
                         "scales with the token count)")
    ap.add_argument("--policy", default="greedy",
                    help="greedy | load | drain | actor:<ckpt_dir> (a "
                         "core.policies actor checkpoint, e.g. the one "
                         "benchmarks/policy_serving.py trains)")
    ap.add_argument("--chunk", type=int, default=None,
                    help="two-phase commit chunk size (None = single-scan "
                         "path; 256 is a good default at fleet scale)")
    ap.add_argument("--backend", default=None,
                    choices=["xla", "pallas", "pallas-interpret"],
                    help="scoring backend (default: $REPRO_ROUTER_BACKEND "
                         "or xla)")
    ap.add_argument("--no-execute", action="store_true",
                    help="route only (no local generation)")
    ap.add_argument("--mesh", type=int, default=None, metavar="D",
                    help="shard routing over D local devices "
                         "(core.mesh_router; the batch is one "
                         "reconciliation window — see docs/sharding.md). "
                         "CPU hosts expose extra devices via "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    args = ap.parse_args()
    stats = serve(args.requests, args.servers, args.policy,
                  execute=not args.no_execute, seed=args.seed,
                  gen_tokens=args.gen_tokens if args.gen_tokens > 0 else None,
                  n_cells=args.cells,
                  drain_rate=args.drain_rate,
                  arrival_rate=args.arrival_rate, chunk=args.chunk,
                  backend=args.backend, scenario=args.scenario,
                  mesh=args.mesh)
    for k, v in stats.items():
        print(f"{k}: {v}")


if __name__ == "__main__":
    main()
