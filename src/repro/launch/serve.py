"""Serving driver: model-aware edge serving of the AIGC model zoo.

Wires the paper's scheduling layer to the real model plane:
  * a fleet of ``EdgeServer``s (device groups), each caching a subset of
    the catalogue (the 10 assigned architectures);
  * the WHOLE request batch routed in one jitted ``core.batch_router``
    call pricing the paper's eq. 5/7/9 cost terms (transmission, model
    switch, FIFO-shared compute) with sequential-commit semantics;
  * actual prefill+decode of the routed batch through ``models.lm`` on
    the local device (reduced configs on CPU).

    python -m repro.launch.serve --requests 64 --servers 3
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, list_archs, reduced
from repro.core import batch_router
from repro.core.catalog import build_catalog
from repro.core.router import EdgeServer
from repro.models import lm


def make_fleet(n_servers: int, catalog, flops=197e12, slots=2):
    return [
        EdgeServer(
            name=f"es{i}", flops_per_s=flops, cache_slots=slots,
            uplink_bps=100e6, backhaul_bps=1e9,
            resident=[(2 * i + j) % len(catalog) for j in range(slots)],
        )
        for i in range(n_servers)
    ]


def serve(num_requests=32, n_servers=3, policy="greedy", execute=True, seed=0,
          gen_tokens=8):
    rng = np.random.default_rng(seed)
    # serve the edge-suitable (small) members of the catalogue
    edge_archs = ["smollm_135m", "starcoder2_3b", "mamba2_2p7b", "musicgen_medium"]
    catalog = build_catalog(edge_archs)
    fleet_params, fleet_state = batch_router.fleet_from_servers(
        make_fleet(n_servers, catalog), catalog
    )

    # local reduced models actually generate tokens for routed requests
    models = {}
    if execute:
        for e in catalog:
            cfg = reduced(get_arch(e.name))
            models[e.index] = (cfg, lm.init_params(jax.random.key(e.index), cfg))

    reqs = batch_router.RequestBatch(
        model=jnp.asarray(rng.integers(0, len(catalog), num_requests), jnp.int32),
        prompt_bits=jnp.asarray(rng.uniform(1e5, 1e6, num_requests), jnp.float32),
        gen_tokens=jnp.full((num_requests,), gen_tokens, jnp.float32),
    )

    # route the WHOLE batch in one jitted call (sequential-commit scan);
    # each routed request drains the fleet like the old per-request loop
    t0 = time.time()
    fleet_state, out = batch_router.route_batch(
        fleet_params, fleet_state, reqs,
        gen_tokens * n_servers / max(num_requests, 1), policy=policy,
    )
    jax.block_until_ready(out.choice)
    route_s = time.time() - t0

    if execute:
        for model_idx in np.asarray(reqs.model):
            cfg, params = models[int(model_idx)]
            B, P = 1, 8
            if cfg.modality == "audio":
                prompt = jnp.zeros((B, P, cfg.num_codebooks), jnp.int32)
            else:
                prompt = jnp.zeros((B, P), jnp.int32)
            ids, _, cache = lm.prefill(params, prompt, cfg)
            # token-by-token generation against a fresh full cache
            full = lm.init_cache(cfg, B, P + gen_tokens)

            def seat(dst, src):
                if src.shape == dst.shape:
                    return src.astype(dst.dtype)
                pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
                return jnp.pad(src, pad).astype(dst.dtype)

            cache = jax.tree.map(seat, full, cache)
            tok = ids[:, -1:]
            for t in range(gen_tokens):
                tok, _, cache = lm.decode_step(
                    params, cache, tok, jnp.int32(P + t), cfg
                )

    stats = batch_router.stats(out)
    stats["route_s"] = route_s
    stats["wall_s"] = time.time() - t0
    stats["requests"] = num_requests
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--servers", type=int, default=3)
    ap.add_argument("--policy", default="greedy", choices=["greedy", "load"])
    ap.add_argument("--no-execute", action="store_true",
                    help="route only (no local generation)")
    args = ap.parse_args()
    stats = serve(args.requests, args.servers, args.policy,
                  execute=not args.no_execute)
    for k, v in stats.items():
        print(f"{k}: {v}")


if __name__ == "__main__":
    main()
