"""Production training driver.

Composes every substrate layer: config -> mesh -> sharded params/opt ->
deterministic data pipeline -> jitted train_step -> atomic checkpoints
with auto-resume -> straggler monitor. One entry point for all 10 archs:

    python -m repro.launch.train --arch smollm_135m --steps 200 \
        --batch 8 --seq 512 [--reduced] [--ckpt-dir /tmp/run1]

On this CPU container use --reduced (same code path, small model); on a
pod the full config + production mesh engage via --mesh single|multi.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import checkpointer
from repro.configs import get_arch, reduced as reduce_cfg
from repro.data import pipeline
from repro.distributed import sharding
from repro.distributed.fault_tolerance import StragglerMonitor
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import lm
from repro.models.train import make_train_step
from repro.optim.adamw import OptState


def train(arch: str, *, steps: int = 100, batch: int = 8, seq: int = 256,
          use_reduced: bool = True, ckpt_dir: str | None = None,
          ckpt_every: int = 50, mesh_kind: str = "host", log_every: int = 10,
          seed: int = 0):
    cfg = get_arch(arch)
    if use_reduced:
        cfg = reduce_cfg(cfg)
    if mesh_kind == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))

    params = lm.init_params(jax.random.key(seed), cfg)
    opt_init, step_fn = make_train_step(cfg, mesh=mesh)
    opt_state = opt_init(params)

    pspecs = sharding.param_specs(params, cfg, mesh)
    pshard = sharding.to_named(pspecs, mesh)
    params = jax.device_put(params, pshard)
    opt_state = OptState(
        step=opt_state.step,
        mu=jax.device_put(opt_state.mu, pshard),
        nu=jax.device_put(opt_state.nu, pshard),
    )
    step_jit = jax.jit(step_fn, donate_argnums=(0, 1))

    dc = pipeline.DataConfig(seq_len=seq, global_batch=batch, vocab=cfg.vocab,
                             seed=seed)

    start = 0
    if ckpt_dir:
        latest = checkpointer.latest_step(ckpt_dir)
        if latest is not None:
            (params, opt_state), extra = checkpointer.restore(
                ckpt_dir, latest, (params, opt_state)
            )
            start = latest
            print(f"[resume] restored step {latest}", flush=True)

    monitor = StragglerMonitor(num_hosts=jax.process_count())
    losses = []
    t0 = time.time()
    for step in range(start, steps):
        monitor.start_step()
        data = pipeline.synthetic_batch(cfg, dc, step)
        params, opt_state, metrics = step_jit(params, opt_state, data)
        monitor.end_step(jax.process_index())
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            tok_s = batch * seq * (step - start + 1) / (time.time() - t0)
            print(
                f"step {step:5d} loss {losses[-1]:.4f} "
                f"gnorm {float(metrics['grad_norm']):.2f} tok/s {tok_s:.0f}",
                flush=True,
            )
        if monitor.stragglers():
            print(f"[straggler] hosts {monitor.stragglers()} over deadline "
                  f"{monitor.deadline():.2f}s — re-dispatch", flush=True)
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            checkpointer.save(ckpt_dir, step + 1, (params, opt_state),
                              extra={"loss": losses[-1]})
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full", action="store_true", help="full (non-reduced) config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="host", choices=["host", "single", "multi"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    _, losses = train(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        use_reduced=not args.full, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, mesh_kind=args.mesh, seed=args.seed,
    )
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
