"""Post-SPMD HLO analysis for the roofline (EXPERIMENTS.md §Roofline).

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE (verified:
a 7-step scanned matmul reports 1/7 of the true FLOPs), so for scanned-
layer models every term would be off by ~num_layers. This module parses
``compiled.as_text()`` (the per-device, post-partitioning module) and
computes, with while-trip-count multipliers applied recursively:

  * ``flops``             — 2 * prod(out) * prod(contracting) per dot
  * ``hbm_bytes``         — per top-level instruction: operands + output
    (fusion internals excluded — they live in registers/VMEM, so this is
    a faithful model of HHBM traffic on TPU)
  * ``collective_bytes``  — per-device link traffic per collective with
    the standard ring formulas (all-reduce 2(g-1)/g, all-gather /
    reduce-scatter (g-1)/g, all-to-all (g-1)/g, collective-permute 1x)

Validated against cost_analysis on scan-free modules (tests/test_hlo_analysis.py).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([a-z][a-z0-9\-]*)\((.*)$"
)


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[str]] = {}
        self.instr_type: dict[str, str] = {}
        self._parse(text)
        self._cost_memo: dict[str, dict] = {}

    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            stripped = line.strip()
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{$", stripped)
            if m and "=" not in stripped.split("(")[0]:
                cur = m.group(2)
                self.computations[cur] = []
                if m.group(1):
                    self.entry = cur
                continue
            if stripped == "}":
                continue
            if cur is None:
                continue
            self.computations[cur].append(stripped)
            im = _INSTR_RE.match(stripped)
            if im:
                name, type_str, _, _ = im.groups()
                self.instr_type[name] = type_str
        if not hasattr(self, "entry"):
            # fall back: computation named main*
            for name in self.computations:
                if "main" in name:
                    self.entry = name
                    break

    # ------------------------------------------------------------------
    def _operands(self, args: str):
        seg = args.split("), ")[0] if "), " in args else args.rstrip(")")
        return [m.group(1) for m in re.finditer(r"%([\w.\-]+)", seg)]

    def _trip_count(self, cond_name: str) -> int:
        """Max integer constant in the condition region (the loop bound)."""
        best = 1
        for line in self.computations.get(cond_name, []):
            for m in re.finditer(r"constant\((\d+)\)", line):
                best = max(best, int(m.group(1)))
            # bounds may live in a fused compare computation
            cm = re.search(r"calls=%([\w.\-]+)", line)
            if cm:
                for l2 in self.computations.get(cm.group(1), []):
                    for m in re.finditer(r"constant\((\d+)\)", l2):
                        best = max(best, int(m.group(1)))
        return best

    def _dot_flops(self, line: str, type_str: str, operands) -> float:
        _, out_dims = _shape_dims(type_str)
        out_n = math.prod(out_dims) if out_dims else 1
        lhs_type = self.instr_type.get(operands[0], "") if operands else ""
        _, lhs_dims = _shape_dims(lhs_type)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        contract = 1
        if m and m.group(1) and lhs_dims:
            for d in m.group(1).split(","):
                contract *= lhs_dims[int(d)]
        return 2.0 * out_n * contract

    def _collective_bytes(self, opcode: str, type_str: str, line: str) -> float:
        size = _shape_bytes(type_str)
        g = 1
        m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
        if m:
            g = len(m.group(1).split(","))
        else:
            m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
            if m:
                g = int(m.group(2))
        if g <= 1:
            return 0.0
        scale = {
            "all-reduce": 2.0 * (g - 1) / g,
            "all-gather": (g - 1) / g,
            "reduce-scatter": float(g - 1),  # output is the scattered shard
            "all-to-all": (g - 1) / g,
            "collective-permute": 1.0,
        }[opcode]
        return size * scale

    # ------------------------------------------------------------------
    def computation_cost(self, name: str) -> dict:
        if name in self._cost_memo:
            return self._cost_memo[name]
        flops = hbm = coll = 0.0
        counts: dict[str, float] = defaultdict(float)
        for line in self.computations.get(name, []):
            im = _INSTR_RE.match(line)
            if not im:
                continue
            iname, type_str, opcode, args = im.groups()
            operands = self._operands(args)
            if opcode == "while":
                bm = re.search(r"body=%?([\w.\-]+)", line)
                cm = re.search(r"condition=%?([\w.\-]+)", line)
                if bm and cm:
                    trips = self._trip_count(cm.group(1))
                    sub = self.computation_cost(bm.group(1))
                    flops += trips * sub["flops"]
                    hbm += trips * sub["hbm_bytes"]
                    coll += trips * sub["collective_bytes"]
                    for k, v in sub["collective_counts"].items():
                        counts[k] += trips * v
                continue
            if opcode in ("call", "conditional"):
                for cm in re.finditer(r"(?:to_apply|calls)=%?([\w.\-]+)", line):
                    sub = self.computation_cost(cm.group(1))
                    flops += sub["flops"]
                    hbm += sub["hbm_bytes"]
                    coll += sub["collective_bytes"]
                    for k, v in sub["collective_counts"].items():
                        counts[k] += v
                continue
            base = opcode.split(".")[0]
            if base.rstrip("-start") in _COLLECTIVES or base in _COLLECTIVES:
                op = base[:-6] if base.endswith("-start") else base
                b = self._collective_bytes(op, type_str, line)
                coll += b
                counts[op] += b
                continue
            if base == "dot":
                flops += self._dot_flops(line, type_str, operands)
            if base == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", line)
                if fm:
                    for l2 in self.computations.get(fm.group(1), []):
                        im2 = _INSTR_RE.match(l2)
                        if im2 and im2.group(3) == "dot":
                            flops += self._dot_flops(
                                l2, im2.group(2), self._operands(im2.group(4))
                            )
            # HBM traffic: output + operand bytes (skip pure control flow).
            # dynamic-slice / dynamic-update-slice move only the slice
            # (in-place buffer semantics) — counting the whole carried
            # buffer per loop iteration would overcount a 126-layer scan
            # by ~100x (observed on the llama3-405b decode cell).
            if base == "dynamic-slice":
                hbm += 2 * _shape_bytes(type_str)  # read slice + write out
            elif base == "dynamic-update-slice":
                upd = self.instr_type.get(operands[1], "") if len(operands) > 1 else ""
                hbm += 2 * _shape_bytes(upd)
            elif base not in ("parameter", "constant", "tuple",
                              "get-tuple-element", "bitcast", "copy-start",
                              "copy-done"):
                hbm += _shape_bytes(type_str)
                for op_name in operands:
                    hbm += _shape_bytes(self.instr_type.get(op_name, ""))
        out = {
            "flops": flops,
            "hbm_bytes": hbm,
            "collective_bytes": coll,
            "collective_counts": dict(counts),
        }
        self._cost_memo[name] = out
        return out

    def entry_cost(self) -> dict:
        return self.computation_cost(self.entry)


def analyze(compiled_text: str) -> dict:
    return HloModule(compiled_text).entry_cost()


def xla_cost_analysis(compiled) -> dict:
    """Version-compat view of ``compiled.cost_analysis()``: newer JAX returns
    one dict, older JAX a one-entry-per-device list of dicts."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return ca
