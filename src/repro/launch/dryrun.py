"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the very first two lines — jax locks the device count on first
init, and only this entry point may see 512 placeholder devices:
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, get_arch, input_specs, list_archs, shape_applicable  # noqa: E402
from repro.distributed import sharding  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.models.train import make_train_step  # noqa: E402
from repro.optim.adamw import OptState  # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def _eval_shape_tree(fn, *args):
    return jax.eval_shape(fn, *args)


def build_cell(arch: str, shape_name: str, mesh, overrides=None):
    """Returns (jitted fn, example args as ShapeDtypeStructs)."""
    cfg = get_arch(arch, **(overrides or {}))
    sh = SHAPES[shape_name]
    gb = sh["global_batch"]

    params_shape = jax.eval_shape(lambda: lm.init_params(jax.random.key(0), cfg))
    pspecs = sharding.param_specs(params_shape, cfg, mesh)
    pshard = sharding.to_named(pspecs, mesh)

    if sh["kind"] == "train":
        opt_init, train_step = make_train_step(cfg, mesh=mesh)
        opt_shape = jax.eval_shape(opt_init, params_shape)
        oshard = OptState(
            step=sharding.to_named(P(), mesh),
            mu=sharding.to_named(pspecs, mesh),
            nu=sharding.to_named(pspecs, mesh),
        )
        batch = input_specs(cfg, shape_name)
        bs = sharding.batch_spec(cfg, mesh, gb)
        bshard = {k: sharding.to_named(bs(v.ndim), mesh) for k, v in batch.items()}
        fn = jax.jit(
            train_step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, sharding.to_named(P(), mesh)),
            donate_argnums=(0, 1),
        )
        return fn, (params_shape, opt_shape, batch), cfg

    if sh["kind"] == "prefill":
        batch = input_specs(cfg, shape_name)
        bs = sharding.batch_spec(cfg, mesh, gb)
        bshard = {k: sharding.to_named(bs(v.ndim), mesh) for k, v in batch.items()}

        def prefill_fn(params, tokens, patch_embeds=None):
            return lm.prefill(params, tokens, cfg, patch_embeds=patch_embeds,
                              mesh=mesh)

        cache_shape = jax.eval_shape(
            lambda: lm.init_cache(cfg, gb, sh["seq_len"])
        )
        cshard = sharding.to_named(
            sharding.cache_specs(cache_shape, cfg, mesh, gb), mesh
        )
        out_shard = (
            sharding.to_named(P(), mesh),
            sharding.to_named(P(), mesh),
            cshard,
        )
        args = [params_shape, batch["tokens"]]
        in_sh = [pshard, bshard["tokens"]]
        if "patch_embeds" in batch:
            args.append(batch["patch_embeds"])
            in_sh.append(bshard["patch_embeds"])
        fn = jax.jit(prefill_fn, in_shardings=tuple(in_sh), out_shardings=out_shard)
        return fn, tuple(args), cfg

    # decode
    batch = input_specs(cfg, shape_name)
    cache_shape = jax.eval_shape(lambda: lm.init_cache(cfg, gb, sh["seq_len"]))
    cshard = sharding.to_named(
        sharding.cache_specs(cache_shape, cfg, mesh, gb), mesh
    )
    bs = sharding.batch_spec(cfg, mesh, gb)
    tshard = sharding.to_named(bs(batch["tokens"].ndim), mesh)

    def decode_fn(params, cache, tokens, pos):
        return lm.decode_step(params, cache, tokens, pos, cfg, mesh=mesh)

    fn = jax.jit(
        decode_fn,
        in_shardings=(pshard, cshard, tshard, sharding.to_named(P(), mesh)),
        out_shardings=(
            sharding.to_named(P(), mesh),
            sharding.to_named(P(), mesh),
            cshard,
        ),
        donate_argnums=(1,),
    )
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return fn, (params_shape, cache_shape, batch["tokens"], pos), cfg


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, overrides=None,
             tag: str = "baseline", verbose=True) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out_path = RESULTS_DIR / f"{arch}_{shape_name}_{mesh_name}_{tag}.json"
    if out_path.exists():
        return json.loads(out_path.read_text())

    cfg = get_arch(arch, **(overrides or {}))
    ok, why = shape_applicable(cfg, shape_name)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "overrides": overrides or {},
    }
    if not ok:
        rec.update(status="skipped", reason=why)
    else:
        t0 = time.time()
        try:
            mesh = make_production_mesh(multi_pod=multi_pod)
            fn, args, cfg = build_cell(arch, shape_name, mesh, overrides)
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = hlo_analysis.xla_cost_analysis(compiled)
            hlo = hlo_analysis.analyze(compiled.as_text())
            rec.update(
                status="ok",
                lower_s=round(t_lower, 1),
                compile_s=round(t_compile, 1),
                memory={
                    "argument_bytes": mem.argument_size_in_bytes,
                    "output_bytes": mem.output_size_in_bytes,
                    "temp_bytes": mem.temp_size_in_bytes,
                    "alias_bytes": mem.alias_size_in_bytes,
                    "peak_device_bytes": mem.argument_size_in_bytes
                    + mem.temp_size_in_bytes
                    + mem.output_size_in_bytes
                    - mem.alias_size_in_bytes,
                },
                xla_cost={
                    "flops_per_device_unscaled": cost.get("flops", 0.0),
                    "bytes_unscaled": cost.get("bytes accessed", 0.0),
                },
                hlo=hlo,
                params=cfg.param_count(),
                active_params=cfg.active_param_count(),
            )
        except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
            rec.update(status="error", error=f"{type(e).__name__}: {e}",
                       trace=traceback.format_exc()[-2000:])
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    if verbose:
        msg = rec["status"]
        if rec["status"] == "ok":
            gb = rec["memory"]["peak_device_bytes"] / 2**30
            msg += (f" peak={gb:.1f}GiB/dev flops={rec['hlo']['flops']:.2e} "
                    f"coll={rec['hlo']['collective_bytes']:.2e}B "
                    f"compile={rec['compile_s']}s")
        elif rec["status"] == "error":
            msg += " " + rec["error"][:160]
        else:
            msg += " " + rec["reason"][:80]
        print(f"[{arch} x {shape_name} x {mesh_name}] {msg}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    args = ap.parse_args()
    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    t0 = time.time()
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                run_cell(arch, shape, multi_pod=mp)
    print(f"dry-run sweep done in {time.time() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
