"""Production mesh factory.

Single pod: 16x16 = 256 chips (v5e pod), axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the
``pod`` axis carries only gradient all-reduce (pure DP across pods,
optionally int8-compressed); ``data`` is batch+FSDP; ``model`` is TP.

A FUNCTION, not a module constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before the first jax
init; smoke tests must keep seeing 1 device).
"""
from __future__ import annotations

import jax

from repro.distributed import sharding


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = 1
    for s in shape:
        ndev *= s
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"mesh {shape} needs {ndev} devices, found {len(devices)}; "
            "launch with XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "for the dry-run"
        )
    return sharding.make_mesh(shape, axes, devices=devices[:ndev])


def make_host_mesh(model: int = 1):
    """Tiny mesh over however many local devices exist (tests/examples)."""
    devices = jax.devices()
    data = len(devices) // model
    # explicit subset: make_mesh refuses to undersubscribe silently
    return sharding.make_mesh((data, model), ("data", "model"),
                              devices=devices[: data * model])
