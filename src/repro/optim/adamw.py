"""Functional AdamW (optax is not available in this environment).

API mirrors optax: ``init_fn(params) -> state``, ``update_fn(grads, state,
params) -> (updates, state)``; apply with ``apply_updates``. Supports
bf16 moment storage (``moment_dtype``) — required to fit 100B+ parameter
optimizer state in HBM (see DESIGN.md §5) — plus global-norm clipping and
cosine LR schedules with linear warmup.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def sched(step):
        warm = peak_lr * (step + 1) / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak_lr + (1 - floor) * peak_lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)

    return sched


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def adamw(
    lr: float | Callable,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    moment_dtype=jnp.float32,
    scan_stacked: bool = False,
):
    """``scan_stacked``: apply the update to stacked (layer-major, ndim>=3)
    leaves one slice at a time via lax.map — the fp32 working copies then
    size with ONE layer, not the whole 126-layer stack (saves ~6 GiB/dev
    on llama3-405b; see EXPERIMENTS.md §Perf)."""
    sched = lr if callable(lr) else (lambda _: lr)

    def init_fn(params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update_fn(grads, state: OptState, params):
        step = state.step + 1
        lr_t = sched(step)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
            v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
            mhat = m32 / (1 - b1**step)
            vhat = v32 / (1 - b2**step)
            u = -lr_t * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype), m32.astype(moment_dtype), v32.astype(moment_dtype)

        def upd_leaf(g, m, v, p):
            if scan_stacked and g.ndim >= 3 and g.shape[0] > 1:
                return jax.lax.map(lambda t: upd(*t), (g, m, v, p))
            return upd(g, m, v, p)

        out = jax.tree.map(upd_leaf, grads, state.mu, state.nu, params)
        updates = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return updates, OptState(step=step, mu=mu, nu=nu)

    return init_fn, update_fn


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
