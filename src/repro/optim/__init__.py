from repro.optim.adamw import (  # noqa: F401
    OptState,
    adamw,
    clip_by_global_norm,
    cosine_schedule,
)
