"""Mesh-sharded fleet routing: cell blocks over devices, cloud reconciled.

``core.batch_router.route_batch`` routes a whole multi-cell fleet in one
jitted call — on ONE device. This module shards that call across a
device mesh: the fleet's cell blocks (the cell-major layout,
``batch_router.CellLayout``) and their request buckets are partitioned
over a 1-axis ``("cells",)`` mesh built by ``distributed.sharding.
make_mesh``; each device routes its cells' traffic locally through the
UNCHANGED ``_route_core`` (same scan / chunked two-phase / speculative
parallel commit), and only the shared ``CLOUD_CELL`` columns are
reconciled afterwards.

Window semantics
----------------
One ``route_batch_sharded`` call is one serving WINDOW (what
``workloads.simulate`` already feeds ``route_batch``). Within a window:

* each cell's requests commit sequentially, in arrival order, against
  the cell's own server block — exactly the single-device semantics,
  because cells are invisible to each other's requests;
* each cell prices the shared cloud columns against the WINDOW-ENTRY
  snapshot of the cloud queue plus the cell's OWN cloud commits. Cells
  do not observe each other's cloud backlog until the window closes —
  the one relaxation that makes the batch parallel across cells.

At window close the shared columns are reconciled:

* **cloud backlog** — the per-cell backlog commits are gathered (an
  all-reduce-sized exchange: the committed choices plus one queue row
  per cell) and replayed in global arrival order by a cheap masked-add
  scan, so the carried cloud queue is the EXACT sequential fold of
  every committed token — bitwise what the single-device path computes
  for the same choices, including the wall-clock drain;
* **cloud LRU** — per-cell ``last_use`` copies hold globally-ordered
  clocks (see below), so an elementwise max is the exact latest-use;
* **cloud residency** — validated full (``launch.serve.
  make_cloud_server`` guarantees it), hence immutable: a full row can
  never install or evict, so the per-cell copies cannot diverge.

Exactness
---------
Decisions, residency, LRU clocks, queues, rejections and the carried
clock are BIT-IDENTICAL to single-device ``route_batch`` (and hence the
scalar oracle) whenever the window's cloud feedback does not cross
cells — cloud-free fleets, or streams whose cloud commits all originate
in one cell — and independent of the device count ALWAYS: the same
window routed on 1, 2, 4 or 8 devices produces identical bits, because
per-cell work is data-independent across cells and the reconciliation
reduces in a fixed, device-count-free order. With cross-cell cloud
contention the carried state is still exact for the committed choices;
the choices themselves follow the window semantics above. With a
nonzero ``drain_rate`` the per-cell decay composes the same real
arithmetic in fewer floating-point steps (one ``dt`` per own-cell
arrival instead of one per global arrival), so edge queues agree to
float tolerance rather than bitwise; ``drain_rate == 0`` (with or
without arrival stamps) is exact.

LRU clocks stay globally ordered through a post-scan remap: each cell
routes with LOCAL clocks ``clock0 + 1 .. clock0 + Bc`` (monotone in its
own stream, so every eviction argmin is unchanged), and committed
entries — recognisable as ``last_use > clock0`` — are rewritten to
``clock0 + 1 + global_position`` through the bucket's request-position
map before the blocks are reassembled.

The legacy per-request ``drain_tokens`` argument is rejected: it drains
EVERY server after EVERY request — a globally-sequential semantics that
cannot be cell-partitioned. Use the time-based ``FleetParams.
drain_rate`` instead.

Robustness knobs
----------------
The single-device robustness knobs (``docs/robustness.md``) thread
through unchanged: ``RequestBatch.deadline_s`` rides the buckets
(padding rows carry ``+inf`` — no SLO), the ``outage`` mask is
cell-blocked like every server column (an outaged cloud column is seen
outaged by every cell, and the reconciliation replay freezes its
drain), and ``outcome.cause`` is derived post-hoc from the scattered
choices by the shared ``batch_router.rejection_cause`` — bitwise the
single-device channel.

The eq. 16 action knobs (``RequestBatch.eta`` / ``beta`` /
``local_flops_per_s``, see ``batch_router.route_batch``) ride the
buckets the same way — padding rows carry ``eta = 1`` (so the ``+inf``
prompt pad never multiplies to NaN), ``beta = True`` and a zero local
rate — and the inner ``_route_core`` applies them per cell untouched.
The only shared-column consequence is the cloud backlog: a partial
offload commits ``eta * gen_tokens``, so the window-close replay folds
the SAME eta-scaled token count (one exact-rounded multiply — bitwise
the per-cell commit). Downloads (``beta``) never need reconciling: the
cloud columns are validated full-residency, so every cross-cell model
fetch lands on a per-cell edge block no other cell can touch.

Neighbour-cell spill (``FleetParams.spill``) breaks the premise of the
cell-blocked path — a request may commit OUTSIDE its home block — so
spill fleets take a FULL-REPLICATION variant instead: every device row
holds the whole fleet, routes its cells' request buckets against the
window-entry snapshot (same window semantics as the cloud columns,
now applied to every server), and the carried state is rebuilt by one
close-replay scan over the committed choices in global arrival order —
the exact sequential fold of ``batch_router._commit``, decay included.
Choices are bit-identical to single-device whenever a window's
cross-cell feedback stays within one bucket (e.g. all real traffic in
one cell), and the carried state is always the exact fold of the
committed choices.

Layout contract
---------------
The fleet must be cell-major (``batch_router.cell_layout``): equal-size
edge cell blocks ``0..C-1`` contiguous, cloud columns trailing —
``launch.serve.make_multicell_fleet`` builds exactly this. Fleets in
any other server order are permuted in (``cell_major_order``) and the
returned state/choices permuted back, so the call is order-preserving
for the caller. Requests need ``RequestBatch.cell`` when C > 1;
out-of-range cells (and requests arriving when no cell matches) see
only the cloud columns, exactly like the single-device mask. Cells
that don't divide the device count are padded with inert all-padding
blocks; padding requests carry ``prompt_bits = +inf`` so every score is
infeasible and the commit machinery provably never touches state.

``benchmarks/fleet_scale.py`` measures req/s vs device count at fleet
scale; ``docs/sharding.md`` is the guide; ``tests/
test_multicell_router.py`` locks the equivalences down on a forced
8-device host.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import batch_router as br
from repro.core.router import CLOUD_CELL
from repro.distributed import sharding

#: Inner cell id for requests that must see ONLY the cloud columns:
#: orphans (out-of-range cells) and bucket padding. Edge blocks are
#: relabeled to cell 0 and the cloud keeps CLOUD_CELL (-1), so -2 can
#: never match a server.
_ORPHAN_CELL = -2

#: Request buckets are padded to a multiple of this so window-to-window
#: jitter in the per-cell request count doesn't recompile the mesh call.
_BUCKET_ROUND = 16


@functools.lru_cache(maxsize=None)
def cells_mesh(num_devices: int):
    """1-axis ``("cells",)`` mesh over the first ``num_devices`` local
    devices (an explicit subset: ``make_mesh`` refuses to silently
    undersubscribe the platform)."""
    return sharding.make_mesh(
        (num_devices,), ("cells",),
        devices=tuple(jax.devices()[:num_devices]),
    )


def local_template_params(params: br.FleetParams) -> br.FleetParams:
    """The block-0 local fleet view every cell shares geometrically:
    ``per_cell`` edge servers relabeled cell 0 + the cloud columns.
    Build policies for the sharded router against THIS template (see
    ``core.policies.actor_policy_for_cell_blocks``)."""
    return br.local_block_params(params, br.cell_layout(params), 0)


def _bucket_requests(reqs: br.RequestBatch, layout: br.CellLayout,
                     c_pad: int, time0: float, has_time: bool,
                     keep_cells: bool = False):
    """Host-side bucketing of a (B,) request stream into dense
    ``(c_pad, bc)`` per-cell buckets (numpy; the result feeds the jitted
    mesh call).

    Real requests keep their arrival order inside their cell's bucket
    and carry inner cell 0; orphans (out-of-range ``cell``) are spread
    deterministically (global index mod C — device-count independent)
    and carry ``_ORPHAN_CELL`` so they see only the cloud. With
    ``keep_cells`` (the full-replication spill path, which routes each
    bucket against GLOBAL params) every request keeps its true cell id
    instead — orphans included, so the global mask prices them exactly
    like the single-device call. Trailing
    padding rows carry ``prompt_bits = +inf`` (every score infeasible →
    rejected → zero state mutation), a ``+inf`` deadline (no SLO) and an
    arrival stamp no later than
    the bucket's running clock (``dt = 0`` → the wall-clock decay is a
    bitwise no-op). ``gpos`` maps each bucket slot back to its global
    stream position (-1 on padding) — the outcome scatter and the LRU
    clock remap both key off it."""
    c = layout.num_cells
    b = int(reqs.model.shape[0])
    model = np.asarray(reqs.model)
    prompt = np.asarray(reqs.prompt_bits)
    gen = np.asarray(reqs.gen_tokens)
    if reqs.cell is not None:
        rcell = np.asarray(reqs.cell).astype(np.int64)
    else:
        rcell = np.zeros(b, np.int64)
    in_range = (rcell >= 0) & (rcell < c)
    bucket = np.where(in_range, rcell, np.arange(b, dtype=np.int64) % c)
    counts = np.bincount(bucket, minlength=c)
    bc = -(-max(int(counts.max()), 1) // _BUCKET_ROUND) * _BUCKET_ROUND
    order = np.argsort(bucket, kind="stable")
    starts = np.zeros(c + 1, np.int64)
    starts[1:] = np.cumsum(counts)
    sortedb = bucket[order]
    slot = np.arange(b, dtype=np.int64) - starts[sortedb]

    gpos = np.full((c_pad, bc), -1, np.int32)
    model_b = np.zeros((c_pad, bc), model.dtype)
    prompt_b = np.full((c_pad, bc), np.inf, prompt.dtype)
    gen_b = np.zeros((c_pad, bc), gen.dtype)
    icell_b = np.full((c_pad, bc), _ORPHAN_CELL, np.int32)
    gpos[sortedb, slot] = order
    model_b[sortedb, slot] = model[order]
    prompt_b[sortedb, slot] = prompt[order]
    gen_b[sortedb, slot] = gen[order]
    if keep_cells:
        icell_b[sortedb, slot] = rcell[order].astype(np.int32)
    else:
        icell_b[sortedb, slot] = np.where(in_range[order], 0, _ORPHAN_CELL)

    dl_b = None
    if reqs.deadline_s is not None:
        dl = np.asarray(reqs.deadline_s)
        dl_b = np.full((c_pad, bc), np.inf, dl.dtype)
        dl_b[sortedb, slot] = dl[order]

    # eq. 16 action columns: padding carries eta = 1 (the +inf prompt
    # pad must not multiply to NaN), beta = True and a zero local rate
    # (t_local is where-guarded on local > 0) — all inert
    eta_b = None
    if reqs.eta is not None:
        eta = np.asarray(reqs.eta)
        eta_b = np.ones((c_pad, bc), eta.dtype)
        eta_b[sortedb, slot] = eta[order]
    beta_b = None
    if reqs.beta is not None:
        beta = np.asarray(reqs.beta, bool)
        beta_b = np.ones((c_pad, bc), bool)
        beta_b[sortedb, slot] = beta[order]
    loc_b = None
    if reqs.eta is not None and reqs.local_flops_per_s is not None:
        loc = np.asarray(reqs.local_flops_per_s)
        loc_b = np.zeros((c_pad, bc), loc.dtype)
        loc_b[sortedb, slot] = loc[order]

    arr_b = None
    if has_time:
        arr = np.asarray(reqs.arrival_s)
        arr_b = np.zeros((c_pad, bc), arr.dtype)
        arr_b[sortedb, slot] = arr[order]
        # padding arrivals: the bucket's latest stamp (or the fleet
        # clock) — never ahead of the inner running time, so dt == 0
        bmax = np.full(c_pad, time0, arr.dtype)
        if b:
            np.maximum.at(bmax, sortedb, arr[order])
        pad_counts = np.zeros(c_pad, np.int64)
        pad_counts[:c] = counts
        padmask = np.arange(bc)[None, :] >= pad_counts[:, None]
        arr_b = np.where(padmask, bmax[:, None], arr_b)
    return (model_b, prompt_b, gen_b, icell_b, arr_b, dl_b, eta_b, beta_b,
            loc_b, gpos)


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "axis", "layout", "c_pad", "policy", "actor",
                     "chunk", "unroll", "backend", "speculative"),
)
def _sharded_route(params, state, model_b, prompt_b, gen_b, icell_b, arr_b,
                   dl_b, eta_b, beta_b, loc_b, outage, gpos_b, gen_g, arr_g,
                   eta_g, *, mesh, axis, layout,
                   c_pad, policy, actor, chunk, unroll, backend, speculative):
    policy_fn = br._resolve_policy(policy, actor)
    c, n, nc = layout.num_cells, layout.per_cell, layout.num_cloud
    ne, m = layout.num_edge, layout.per_cell + layout.num_cloud
    bc = int(model_b.shape[1])
    b = int(gen_g.shape[0])
    dtype = jnp.result_type(prompt_b, params.uplink_bps)
    has_time = params.drain_rate is not None and arr_b is not None
    has_dl = dl_b is not None
    has_eta = eta_b is not None
    has_beta = beta_b is not None
    has_loc = loc_b is not None
    has_outage = outage is not None
    clock0 = state.clock
    time0 = jnp.asarray(
        state.time_s if state.time_s is not None else 0.0, dtype
    )
    queue0 = state.queue_tokens.astype(dtype)

    def blocks(x):
        """(N, ...) server-major -> (c_pad, n+nc, ...) cell blocks, the
        cloud rows replicated into every block, padded cells inert
        copies of block 0 (their requests are all padding)."""
        blk = x[:ne].reshape((c, n) + x.shape[1:])
        if nc:
            cloud = jnp.broadcast_to(x[ne:][None], (c, nc) + x.shape[1:])
            blk = jnp.concatenate([blk, cloud], axis=1)
        if c_pad > c:
            blk = jnp.concatenate(
                [blk, jnp.broadcast_to(blk[:1], (c_pad - c,) + blk.shape[1:])]
            )
        return blk

    local_cell = jnp.concatenate([
        jnp.zeros((n,), jnp.int32),
        jnp.full((nc,), CLOUD_CELL, jnp.int32),
    ]) if nc else jnp.zeros((n,), jnp.int32)

    has_drain = params.drain_rate is not None
    ins = [
        blocks(params.flops_per_s), blocks(params.uplink_bps),
        blocks(params.backhaul_bps), blocks(params.cache_slots),
        blocks(state.resident), blocks(state.last_use), blocks(queue0),
        model_b, prompt_b, gen_b, icell_b, gpos_b,
    ]
    if has_drain:
        ins.append(blocks(params.drain_rate))
    if has_time:
        ins.append(arr_b)
    if has_dl:
        ins.append(dl_b)
    if has_eta:
        ins.append(eta_b)
    if has_beta:
        ins.append(beta_b)
    if has_loc:
        ins.append(loc_b)
    if has_outage:
        ins.append(blocks(outage))
    n_shard = len(ins)
    repl = [params.size_bits, params.decode_flops_per_token, clock0, time0,
            local_cell]

    def device_fn(*args):
        sh = args[:n_shard]
        size_bits, dflops, clk0, t0, lcell = args[n_shard:]

        def one_cell(cell_args):
            (fl, up, bh, slots, res, lu, q, mdl, pr, gn, icl,
             gp, *rest) = cell_args
            rest = list(rest)
            dr = rest.pop(0) if has_drain else None
            ar = rest.pop(0) if has_time else None
            dl = rest.pop(0) if has_dl else None
            et = rest.pop(0) if has_eta else None
            bt = rest.pop(0) if has_beta else None
            lc = rest.pop(0) if has_loc else None
            og = rest.pop(0) if has_outage else None
            p = br.FleetParams(
                flops_per_s=fl, uplink_bps=up, backhaul_bps=bh,
                cache_slots=slots, size_bits=size_bits,
                decode_flops_per_token=dflops, cell=lcell, drain_rate=dr,
            )
            s = br.FleetState(resident=res, last_use=lu, queue_tokens=q,
                              clock=clk0, time_s=t0)
            r = br.RequestBatch(model=mdl, prompt_bits=pr, gen_tokens=gn,
                                cell=icl, arrival_s=ar, deadline_s=dl,
                                eta=et, beta=bt, local_flops_per_s=lc)
            st, out = br._route_core(p, s, r, None, policy_fn, chunk=chunk,
                                     unroll=unroll, backend=backend,
                                     speculative=speculative, outage=og)
            # local -> global LRU clock remap: commits from THIS window
            # (> clock0 — stale entries, including pre-window values,
            # never exceed the entry clock) are rewritten to clock0 + 1
            # + global stream position through the bucket position map
            cmap = clk0 + 1 + gp
            lu2 = st.last_use
            fresh = lu2 > clk0
            lu2 = jnp.where(
                fresh, cmap[jnp.clip(lu2 - clk0 - 1, 0, bc - 1)], lu2
            )
            return (st.resident, lu2, st.queue_tokens.astype(dtype),
                    out.choice, out.latency, out.hit)

        return jax.vmap(one_cell)(sh)

    routed = sharding.shard_map(
        device_fn, mesh=mesh,
        in_specs=(P(axis),) * n_shard + (P(),) * len(repl),
        out_specs=(P(axis),) * 6, check_vma=False,
    )(*ins, *repl)
    res_o, lu_o, q_o, ch_o, lat_o, hit_o = routed

    # --- reassemble the cell-major fleet state (real cells only) ---
    num_k = int(params.size_bits.shape[0])
    resident = res_o[:c, :n].reshape(ne, num_k)
    last_use = lu_o[:c, :n].reshape(ne, num_k)
    queue = q_o[:c, :n].reshape(ne)

    # --- scatter outcomes back to the caller's stream order ---
    imap = (jnp.arange(c_pad, dtype=jnp.int32) * n)[:, None] \
        + jnp.arange(n, dtype=jnp.int32)[None, :]
    if nc:
        imap = jnp.concatenate([
            imap,
            jnp.broadcast_to(ne + jnp.arange(nc, dtype=jnp.int32),
                             (c_pad, nc)),
        ], axis=1)
    ch_glob = jnp.where(
        ch_o >= 0,
        jnp.take_along_axis(imap, jnp.clip(ch_o, 0, m - 1), axis=1),
        -1,
    )
    gposf = gpos_b.reshape(-1)
    safe = jnp.where(gposf >= 0, gposf, b)  # b: out of bounds -> dropped
    choice = jnp.zeros((b,), jnp.int32).at[safe].set(
        ch_glob.reshape(-1), mode="drop")
    latency = jnp.zeros((b,), dtype).at[safe].set(
        lat_o.reshape(-1).astype(dtype), mode="drop")
    hit = jnp.zeros((b,), bool).at[safe].set(hit_o.reshape(-1), mode="drop")

    # --- cloud reconciliation ---
    if nc:
        # residency: validated full at entry -> immutable; carry as-is
        resident = jnp.concatenate([resident, state.resident[ne:]])
        # LRU: per-cell copies hold globally-ordered clocks after the
        # remap, so the elementwise max IS the latest use
        lu_cloud = jnp.maximum(jnp.max(lu_o[:c, n:], axis=0),
                               state.last_use[ne:])
        last_use = jnp.concatenate([last_use, lu_cloud])
        # backlog: replay the committed cloud choices in global arrival
        # order — the exact sequential fold the single-device scan
        # computes, decay included (see module docstring)
        cloud_ids = ne + jnp.arange(nc, dtype=jnp.int32)
        rate_cloud = (params.drain_rate[ne:].astype(dtype)
                      if has_time else None)
        if has_time and has_outage:
            # frozen queue: an outaged cloud column stops draining, in
            # the replay exactly as in every per-cell scan
            rate_cloud = jnp.where(outage[ne:], 0.0, rate_cloud)

        def replay_step(carry, xs):
            qc, trun = carry
            if has_time:
                ch_i, g_i, a_i = xs
                dt = jnp.maximum(a_i - trun, 0.0)
                trun = jnp.maximum(trun, a_i)
                qc = jnp.maximum(qc - rate_cloud * dt, 0.0)
            else:
                ch_i, g_i = xs
            qc = qc + jnp.where(cloud_ids == ch_i, g_i, 0.0)
            return (qc, trun), None

        # a partial offload commits eta * gen_tokens (one exact-rounded
        # multiply — the same bits every per-cell scan folded)
        gen_rep = gen_g.astype(dtype)
        if eta_g is not None:
            gen_rep = gen_rep * eta_g.astype(dtype)
        xs = (choice, gen_rep)
        if has_time:
            xs += (arr_g.astype(dtype),)
        (q_cloud, _), _ = jax.lax.scan(
            replay_step, (queue0[ne:], time0), xs, unroll=min(64, b))
        queue = jnp.concatenate([queue, q_cloud])

    clock_f = clock0 + jnp.asarray(b, clock0.dtype)
    if has_time:
        time_f = jnp.maximum(time0, jnp.max(arr_g.astype(dtype)))
    else:
        time_f = time0
    new_state = br.FleetState(resident=resident, last_use=last_use,
                              queue_tokens=queue, clock=clock_f,
                              time_s=time_f)
    return new_state, br.RouteOutcome(choice=choice, latency=latency,
                                      hit=hit)


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "axis", "c_pad", "policy", "actor", "chunk",
                     "unroll", "backend", "speculative"),
)
def _sharded_route_spill(params, state, model_b, prompt_b, gen_b, icell_b,
                         arr_b, dl_b, eta_b, beta_b, loc_b, outage, gpos_b,
                         model_g, gen_g, arr_g, eta_g,
                         *, mesh, axis, c_pad, policy, actor, chunk, unroll,
                         backend, speculative):
    """Full-replication sharded route for spill fleets (module docstring:
    robustness knobs). Every device row holds the WHOLE fleet; each cell
    bucket routes against the window-entry snapshot with the GLOBAL
    params (true cell ids, global spill adjacency — choices come out in
    global server indices, so no LRU remap and no index map), and the
    carried state is rebuilt by one close-replay scan over the committed
    choices in global arrival order: the exact ``batch_router._commit``
    fold, wall-clock decay and outage freeze included."""
    policy_fn = br._resolve_policy(policy, actor)
    b = int(model_g.shape[0])
    dtype = jnp.result_type(prompt_b, params.uplink_bps)
    has_time = params.drain_rate is not None and arr_b is not None
    has_dl = dl_b is not None
    has_eta = eta_b is not None
    has_beta = beta_b is not None
    has_loc = loc_b is not None
    has_outage = outage is not None
    clock0 = state.clock
    time0 = jnp.asarray(
        state.time_s if state.time_s is not None else 0.0, dtype
    )
    queue0 = state.queue_tokens.astype(dtype)

    sharded = [model_b, prompt_b, gen_b, icell_b]
    if has_time:
        sharded.append(arr_b)
    if has_dl:
        sharded.append(dl_b)
    if has_eta:
        sharded.append(eta_b)
    if has_beta:
        sharded.append(beta_b)
    if has_loc:
        sharded.append(loc_b)
    n_shard = len(sharded)
    repl = [params, state] + ([outage] if has_outage else [])

    def device_fn(*args):
        sh = args[:n_shard]
        p_full, s_full = args[n_shard], args[n_shard + 1]
        og = args[n_shard + 2] if has_outage else None

        def one_bucket(cell_args):
            mdl, pr, gn, icl, *rest = cell_args
            rest = list(rest)
            ar = rest.pop(0) if has_time else None
            dl = rest.pop(0) if has_dl else None
            et = rest.pop(0) if has_eta else None
            bt = rest.pop(0) if has_beta else None
            lc = rest.pop(0) if has_loc else None
            r = br.RequestBatch(model=mdl, prompt_bits=pr, gen_tokens=gn,
                                cell=icl, arrival_s=ar, deadline_s=dl,
                                eta=et, beta=bt, local_flops_per_s=lc)
            _, out = br._route_core(p_full, s_full, r, None, policy_fn,
                                    chunk=chunk, unroll=unroll,
                                    backend=backend, speculative=speculative,
                                    outage=og)
            # per-bucket state is discarded: the close replay below is
            # the single source of truth for the carried fleet
            return out.choice, out.latency, out.hit

        return jax.vmap(one_bucket)(sh)

    ch_o, lat_o, hit_o = sharding.shard_map(
        device_fn, mesh=mesh,
        in_specs=(P(axis),) * n_shard + (P(),) * len(repl),
        out_specs=(P(axis),) * 3, check_vma=False,
    )(*sharded, *repl)

    # --- scatter outcomes back to the caller's stream order ---
    gposf = gpos_b.reshape(-1)
    safe = jnp.where(gposf >= 0, gposf, b)  # b: out of bounds -> dropped
    choice = jnp.zeros((b,), jnp.int32).at[safe].set(
        ch_o.reshape(-1), mode="drop")
    latency = jnp.zeros((b,), dtype).at[safe].set(
        lat_o.reshape(-1).astype(dtype), mode="drop")
    hit = jnp.zeros((b,), bool).at[safe].set(hit_o.reshape(-1), mode="drop")

    # --- close replay: sequential fold of the committed choices ---
    drain_rate = params.drain_rate.astype(dtype) if has_time else None
    if drain_rate is not None and has_outage:
        drain_rate = jnp.where(outage, 0.0, drain_rate)
    nsrv = int(params.flops_per_s.shape[0])

    def commit_step(carry, xs):
        resident, last_use, queue, clock, time_s = carry
        if has_time:
            model, gen_i, ch_i, a_i = xs
            dt = jnp.maximum(a_i - time_s, 0.0)
            queue = jnp.maximum(queue - drain_rate * dt, 0.0)
            time_s = jnp.maximum(time_s, a_i)
        else:
            model, gen_i, ch_i = xs
        clock = clock + 1
        ok = ch_i >= 0
        sel = jnp.clip(ch_i, 0, nsrv - 1)
        # _commit's ok-gated branch, expression for expression
        row = resident[sel]
        was_resident = row[model]
        full = row.sum() >= params.cache_slots[sel]
        evict_idx = jnp.argmin(
            jnp.where(row, last_use[sel], jnp.iinfo(jnp.int32).max)
        )
        evict = ~was_resident & full & ok
        row = row.at[evict_idx].set(row[evict_idx] & ~evict)
        row = row.at[model].set(row[model] | ok)
        resident = resident.at[sel].set(row)
        last_use = last_use.at[sel, model].set(
            jnp.where(ok, clock, last_use[sel, model])
        )
        queue = queue.at[sel].add(jnp.where(ok, gen_i, 0.0))
        return (resident, last_use, queue, clock, time_s), None

    # a partial offload commits eta * gen_tokens — same bits as the
    # per-cell scans (one exact-rounded multiply, see _sharded_route)
    gen_rep = gen_g.astype(dtype)
    if eta_g is not None:
        gen_rep = gen_rep * eta_g.astype(dtype)
    xs = (model_g, gen_rep, choice)
    if has_time:
        xs += (arr_g.astype(dtype),)
    carry = (state.resident, state.last_use, queue0, clock0, time0)
    (resident, last_use, queue, clock_f, time_f), _ = jax.lax.scan(
        commit_step, carry, xs, unroll=min(64, b))

    new_state = br.FleetState(resident=resident, last_use=last_use,
                              queue_tokens=queue, clock=clock_f,
                              time_s=time_f)
    return new_state, br.RouteOutcome(choice=choice, latency=latency,
                                      hit=hit)


def route_batch_sharded(
    params: br.FleetParams,
    state: br.FleetState,
    reqs: br.RequestBatch,
    drain_tokens=None,
    *,
    outage=None,
    mesh=None,
    num_devices: Optional[int] = None,
    policy="greedy",
    actor=None,
    chunk: Optional[int] = None,
    unroll: int = 8,
    backend: Optional[str] = None,
    speculative: bool = True,
):
    """Route one request window across a device mesh; returns
    ``(state, outcome)`` with the same pytrees as ``route_batch``.

    The fleet's cell blocks and their request buckets are partitioned
    over the mesh's leading axis; each device routes its cells locally
    through the unchanged scan/chunked/speculative machinery, and the
    shared cloud columns are reconciled at window close (module
    docstring: window semantics, exactness, layout contract).

    Robustness knobs match ``route_batch``: ``reqs.deadline_s`` (SLO
    admission), ``outage`` ((N,) bool fault mask in the caller's server
    order) and ``params.spill`` — the last switches to the
    full-replication path (module docstring: robustness knobs). The
    eq. 16 action knobs (``reqs.eta`` / ``beta`` /
    ``local_flops_per_s``) ride the buckets and the cloud replay folds
    the eta-scaled commit (module docstring). ``outcome.cause`` labels
    every rejection.

    Mesh selection: pass ``mesh`` (leading axis = the cell axis) or
    ``num_devices`` (a 1-axis ``("cells",)`` mesh over the first that
    many local devices); the default uses every local device. Policy /
    ``chunk`` / ``unroll`` / ``backend`` / ``speculative`` knobs match
    ``route_batch`` and configure the per-cell inner path.
    """
    if drain_tokens is not None:
        raise ValueError(
            "drain_tokens drains every server after every request — a "
            "globally-sequential semantics the sharded router cannot "
            "honour; use the time-based FleetParams.drain_rate instead"
        )
    backend = br.resolve_backend(backend)
    if mesh is None:
        d = int(num_devices) if num_devices else len(jax.devices())
        mesh = cells_mesh(d)
    else:
        d = int(mesh.shape[mesh.axis_names[0]])
    axis = mesh.axis_names[0]

    order = None
    try:
        layout = br.cell_layout(params)
    except ValueError:
        if params.cell is None:
            raise
        order = br.cell_major_order(params.cell)
        params, state = br.permute_fleet(params, state, order)
        layout = br.cell_layout(params)  # unequal cells still raise here
    c = layout.num_cells
    if outage is not None:
        outage = np.asarray(outage, bool)
        if order is not None:  # follow the cell-major server permutation
            outage = outage[order]
        outage = jnp.asarray(outage)

    if layout.num_cells > 1 and reqs.cell is None:
        raise ValueError("multi-cell sharded routing needs RequestBatch.cell")
    if layout.num_cloud and not np.asarray(
            state.resident)[layout.num_edge:].all():
        raise ValueError(
            "sharded routing requires full-residency cloud columns (see "
            "launch.serve.make_cloud_server): a cloud row that can still "
            "install or evict would diverge across its per-cell copies"
        )

    b = int(reqs.model.shape[0])
    if b == 0:  # nothing to shard; keep the single-device fast path
        return br.route_batch(params, state, reqs, policy=policy,
                              actor=actor, chunk=chunk, unroll=unroll,
                              backend=backend, speculative=speculative,
                              outage=outage)

    c_pad = -(-c // d) * d
    has_time = params.drain_rate is not None and reqs.arrival_s is not None
    time0 = float(np.asarray(state.time_s)) if state.time_s is not None \
        else 0.0
    has_spill = params.spill is not None and params.cell is not None
    (model_b, prompt_b, gen_b, icell_b, arr_b, dl_b, eta_b, beta_b, loc_b,
     gpos) = _bucket_requests(
        reqs, layout, c_pad, time0, has_time, keep_cells=has_spill)

    route_fn = _sharded_route_spill if has_spill else _sharded_route
    layout_kw = {} if has_spill else {"layout": layout}
    first = (reqs.model,) if has_spill else ()
    new_state, out = route_fn(
        params, state,
        jnp.asarray(model_b), jnp.asarray(prompt_b), jnp.asarray(gen_b),
        jnp.asarray(icell_b),
        None if arr_b is None else jnp.asarray(arr_b),
        None if dl_b is None else jnp.asarray(dl_b),
        None if eta_b is None else jnp.asarray(eta_b),
        None if beta_b is None else jnp.asarray(beta_b),
        None if loc_b is None else jnp.asarray(loc_b),
        outage,
        jnp.asarray(gpos),
        *first,
        reqs.gen_tokens,
        reqs.arrival_s if has_time else None,
        reqs.eta,
        mesh=mesh, axis=axis, c_pad=c_pad, policy=policy,
        actor=actor, chunk=chunk, unroll=unroll, backend=backend,
        speculative=speculative, **layout_kw,
    )
    # the cause channel is a post-hoc pure function of visibility, the
    # outage mask and the scattered choices — shared with every other
    # path, so the sharded rates agree bitwise (docs/robustness.md)
    out = out._replace(
        cause=br.rejection_cause(params, reqs, outage, out.choice))

    if order is not None:  # restore the caller's server ordering
        inv = np.argsort(order)
        _, new_state = br.permute_fleet(params, new_state, inv)
        order_j = jnp.asarray(order, jnp.int32)
        ch = out.choice
        out = out._replace(choice=jnp.where(
            ch >= 0, order_j[jnp.clip(ch, 0, order_j.shape[0] - 1)], -1))
    return new_state, out
