"""Core datatypes for the MADDPG-MATO offloading plane.

Everything is a NamedTuple of JAX arrays so the whole environment +
training loop stays inside jit/scan. Static experiment geometry lives in
``EnvParams`` (hashable leaves are python scalars; array leaves are
per-entity constants sampled once at construction).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

MB_TO_BITS = 8.0e6  # 1 MByte = 8e6 bits


class EnvParams(NamedTuple):
    """Static parameters of one IIoT offloading experiment (paper §IV.A)."""

    # population
    num_eds: int            # M
    num_ess: int            # N
    num_models: int         # K (== number of AIGC task types; type k needs model k)
    cache_slots: int        # models an ES can hold simultaneously

    # compute (Hz) — paper: CC 40 GHz, ES 7 GHz, ED ~ U[1,3] GHz
    f_cc: float
    f_es: float
    f_ed_lo: float
    f_ed_hi: float

    # task distribution — paper: size U[2,20] MB; density in cycles/bit
    task_mb_lo: float
    task_mb_hi: float
    rho_lo: float
    rho_hi: float

    # model catalogue — sizes in bits, len K. Paper: U[90, 250] MB.
    # (tuples of python floats so EnvParams stays hashable / jit-static)
    model_bits: tuple
    # per-task-type importance weight sigma_l, len K
    sigma: tuple
    # per-task-type completion deadline (s), len K
    deadline: tuple

    # radio / backhaul
    bandwidth_hz: float     # uplink bandwidth pool per ES (B_n^max)
    noise_w_per_hz: float   # N0
    tx_power_w: float       # p_m^n
    pathloss_ref: float     # channel gain at 1 m
    pathloss_exp: float     # alpha
    backhaul_bps: float     # r_c^n (CC -> ES model download)
    backhaul_power_w: float # p_c^n

    # energy model — effective switched capacitance
    kappa_ed: float
    kappa_es: float

    # reward shaping (paper eq. 18)
    w_latency: float        # w1
    w_energy: float         # w2
    latency_scale: float    # T normaliser used inside the reward
    energy_scale: float     # E normaliser used inside the reward
    penalty: float          # P_e

    # geometry
    area_m: float           # square side
    episode_len: int        # steps per episode

    # faithfulness switch: use eqs. (4)/(10)/(14) exactly as printed
    faithful: bool

    # cell topology: EDs/ESs are partitioned round-robin into this many
    # edge cells; offloading is only feasible within the ED's own cell
    # (1 — the default — reproduces the paper's single-cell setting)
    num_cells: int = 1


class Task(NamedTuple):
    """One AIGC task per ED (paper eq. 1), vectorised over M."""

    mu: jnp.ndarray    # (M,) int32 task type in [0, K)
    x_bits: jnp.ndarray  # (M,) float32 task size in bits
    rho: jnp.ndarray   # (M,) float32 computational density, cycles/bit


class EnvState(NamedTuple):
    key: jnp.ndarray
    t: jnp.ndarray            # int32 step inside the episode
    ed_pos: jnp.ndarray       # (M, 2) metres
    es_pos: jnp.ndarray       # (N, 2)
    cc_pos: jnp.ndarray       # (2,)
    f_ed: jnp.ndarray         # (M,) Hz
    cache: jnp.ndarray        # (N, K) float32 {0,1} — model residency
    last_use: jnp.ndarray     # (N, K) int32 — LRU clock
    task: Task                # current task batch


class Action(NamedTuple):
    """Executed (discrete) action per ED."""

    target: jnp.ndarray  # (M,) int32 in [0, N]; 0 == local, k>0 == ES k-1
    eta: jnp.ndarray     # (M,) float32 offload ratio in [0,1]
    beta: jnp.ndarray    # (M,) float32 {0,1} — download if missing


class StepOutcome(NamedTuple):
    """Per-agent metrics from one environment step."""

    latency: jnp.ndarray      # (M,) seconds, T_total (eq. 13)
    energy: jnp.ndarray       # (M,) joules, E_total (eq. 14)
    completed: jnp.ndarray    # (M,) float32 {0,1}
    failed_compat: jnp.ndarray  # (M,) float32 {0,1} — offloaded to ES w/o model, no download
    reward: jnp.ndarray       # (M,)
    switch_latency: jnp.ndarray  # (M,) — model-switch component (eq. 7)


def action_dim(num_ess: int) -> int:
    """Continuous action-vector layout: [target one-hot (N+1) | eta | beta]."""
    return num_ess + 1 + 2


def flat_action(act: Action, num_ess: int) -> jnp.ndarray:
    onehot = jnp.eye(num_ess + 1, dtype=jnp.float32)[act.target]
    return jnp.concatenate(
        [onehot, act.eta[..., None], act.beta[..., None]], axis=-1
    )
