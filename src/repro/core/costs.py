"""Latency / energy model — paper §II.C eqs. (3)–(15), implemented verbatim.

Every equation is its own function so the tests can pin each one against
the printed formula. ``faithful`` selects the paper-as-printed variants
(eq. 4 with no ``(1-eta)`` factor, eq. 10 with no ``eta`` factor, eq. 14
taking ``max`` of energies); the corrected variants apply the obvious
data-split factors and sum energies. Benchmarks run corrected mode; both
are unit-tested.
"""
from __future__ import annotations

import jax.numpy as jnp


# --- eq. (3): local computation latency -------------------------------------
def local_latency(x_bits, eta, rho, f_ed):
    return x_bits * (1.0 - eta) * rho / f_ed


# --- eq. (4): local energy. Printed as E = c(f) * x * rho with c(f) = kappa f^2
def local_energy_faithful(x_bits, eta, rho, kappa, f_ed):
    del eta  # the printed equation has no (1 - eta) factor
    return kappa * f_ed**2 * x_bits * rho


def local_energy_corrected(x_bits, eta, rho, kappa, f_ed):
    return kappa * f_ed**2 * x_bits * (1.0 - eta) * rho


# --- eq. (5)/(6): uplink transmission ----------------------------------------
def trans_latency(x_bits, eta, rate_bps):
    return x_bits * eta / rate_bps


def trans_energy(p_tx, t_trans):
    return p_tx * t_trans


# --- eq. (7)/(8): model switching (download from CC) -------------------------
def switch_latency(model_bits, backhaul_bps):
    return model_bits / backhaul_bps


def switch_energy(p_backhaul, t_switch):
    return p_backhaul * t_switch


# --- eq. (9): ES computation latency -----------------------------------------
def edge_latency(x_bits, eta, rho, f_es):
    return x_bits * eta * rho / f_es


# --- eq. (10): ES energy ------------------------------------------------------
def edge_energy_faithful(x_bits, eta, rho, kappa_es, f_es):
    del eta  # printed without the eta factor
    return kappa_es * f_es**2 * x_bits * rho


def edge_energy_corrected(x_bits, eta, rho, kappa_es, f_es):
    return kappa_es * f_es**2 * x_bits * eta * rho


# --- eq. (11)/(12): edge-side totals ------------------------------------------
def edge_total_latency(t_trans, t_switch, t_comp):
    return t_trans + t_switch + t_comp


def edge_score_matrix(prompt_bits, size_bits, flops_tok, work,
                      uplink_bps, backhaul_bps, flops_per_s,
                      queue_tokens=None, resident=None, eta=None,
                      beta=None):
    """Vectorised eq. 11 over ALL request x server pairs: the (B, N) score.

    Per-request columns (B,): ``prompt_bits``, ``size_bits`` (the tagged
    model's weights), ``flops_tok`` (decode FLOPs/token), ``work``
    (``gen_tokens * flops_tok``). Per-server columns (N,): ``uplink_bps``,
    ``backhaul_bps``, ``flops_per_s``, ``queue_tokens``. ``resident`` is
    the (B, N) residency gate (model already cached -> no eq. 7 price).

    ``resident=None`` leaves the switch price UNGATED; ``size_bits=None``
    drops the eq. 7 term entirely and ``queue_tokens=None`` drops the
    backlog term — the latter two yield the state-independent
    "switch-free base" the chunked router adds its per-step residue to
    (the gated switch must be re-applied in the scan: pre-adding it and
    subtracting on residency would cancel catastrophically, since the
    download price dwarfs the served latencies). This function is the
    single home of the eq. 5 + 7 + 9 arithmetic: the XLA scoring path,
    the Pallas kernel oracle and the batched router all call it (or
    reproduce it term for term).

    ``eta`` (B,) is the eq. 16 offload ratio: the edge side only
    transmits and computes the offloaded fraction, so it scales
    ``prompt_bits`` (eq. 5) and ``work`` (eq. 9) — the eq. 3 local
    remainder ``(1-eta)`` lives with the caller (it is per-request, not
    per-pair). ``beta`` (B,) is the download decision: ``False`` refuses
    the eq. 7 model fetch, pricing every non-resident pair at ``+inf``
    (resident pairs are untouched — there is nothing to download).
    ``eta=None`` / ``beta=None`` compile the knobs out bit-identically
    (eta=None prices like eta=1, today's full-offload serving).
    """
    prompt_bits, size_bits, work = apply_eta_beta(
        prompt_bits, size_bits, work, eta, beta
    )
    t_trans = trans_latency(prompt_bits[:, None], 1.0, uplink_bps[None, :])
    if queue_tokens is None:
        backlog = work[:, None]
    else:
        backlog = queue_tokens[None, :] * flops_tok[:, None] + work[:, None]
    t_comp = backlog / flops_per_s[None, :]
    if size_bits is None:
        return t_trans + t_comp
    t_switch = switch_latency(size_bits[:, None], backhaul_bps[None, :])
    if resident is not None:
        t_switch = jnp.where(resident, 0.0, t_switch)
    return edge_total_latency(t_trans, t_switch, t_comp)


def apply_eta_beta(prompt_bits, size_bits, work, eta, beta):
    """Fold the eq. 16 ``(eta, beta)`` knobs into the eq. 5/7/9 inputs.

    Returns ``(prompt_bits, size_bits, work)`` with ``eta`` scaling the
    transmitted bits and offloaded work (``x * eta / r`` groups as
    ``(x * eta) / r`` in IEEE order, so pre-scaling is bit-identical to
    scaling inside eq. 5/9) and ``beta=False`` poisoning the model size
    to ``+inf`` — the eq. 7 switch price becomes ``+inf`` on every
    non-resident pair while the residency gate still zeroes it on hits.
    Shared by the XLA reference, the Pallas kernel wrapper and the
    batched router so all backends transform identically.
    """
    if eta is not None:
        prompt_bits = prompt_bits * eta
        work = work * eta
    if beta is not None:
        if size_bits is None:
            raise ValueError(
                "beta (download refusal) needs size_bits: the switch-free "
                "base has no eq. 7 term to refuse"
            )
        beta = jnp.asarray(beta)
        size_bits = jnp.where(beta.astype(bool), size_bits, jnp.inf)
    return prompt_bits, size_bits, work


def edge_total_energy(e_trans, e_switch, e_comp):
    return e_trans + e_switch + e_comp


# --- eq. (13)/(14): task totals (ED and ES run concurrently) -------------------
def total_latency(t_local, t_edge):
    return jnp.maximum(t_local, t_edge)


def total_energy(e_local, e_edge, faithful: bool):
    if faithful:
        return jnp.maximum(e_local, e_edge)  # as printed
    return e_local + e_edge  # physically additive


# --- eq. (15): scalar objective -----------------------------------------------
def objective(t_total, e_total, theta1, theta2):
    return theta1 * t_total + theta2 * e_total


# --- radio model (paper assumes a rate r_m^n; we use Shannon + log-distance) ---
def shannon_rate(bandwidth_hz, p_tx, gain, noise_w_per_hz):
    snr = p_tx * gain / (noise_w_per_hz * bandwidth_hz)
    return bandwidth_hz * jnp.log2(1.0 + snr)


def channel_gain(dist_m, ref_gain, alpha):
    return ref_gain * jnp.maximum(dist_m, 1.0) ** (-alpha)
