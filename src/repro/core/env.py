"""Vectorised IIoT AIGC-offloading environment (paper §II), pure JAX.

One ``EnvState`` simulates M edge devices (EDs), N edge servers (ESs) and a
cloud centre (CC). Each step every ED carries one AIGC task and executes an
``Action`` (offload target, ratio eta, download flag beta). The step applies
the paper's latency/energy equations, resolves uplink-bandwidth and
ES-compute contention, updates the per-ES model caches with LRU eviction,
and emits per-agent rewards (eq. 18 inner term).

All control flow is array arithmetic — the step jits and vmaps over
parallel environments.

Cell topology: with ``EnvParams.num_cells > 1`` the EDs and ESs are
partitioned round-robin into edge cells (``ed_cell``/``es_cell``);
offloading to an out-of-cell ES is infeasible (counted like a
compatibility failure) and the observation's compatibility map only
shows in-cell residency. ``num_cells == 1`` (the default) reproduces
the paper's single-cell setting bit for bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.types import (
    MB_TO_BITS,
    Action,
    EnvParams,
    EnvState,
    StepOutcome,
    Task,
)


def default_params(
    num_eds: int = 10,
    num_models: int = 3,
    num_ess: int = 3,
    key: int | None = None,
    faithful: bool = False,
    num_cells: int = 1,
) -> EnvParams:
    """Paper §IV.A constants; unspecified ones documented in configs/paper_iiot.

    ``key`` is an integer seed for the (static) model catalogue.
    """
    import numpy as np

    if num_cells > num_ess:
        # round-robin assignment would leave cells with EDs but no ES:
        # every offload there is permanently infeasible
        raise ValueError(
            f"num_cells={num_cells} > num_ess={num_ess}: some cells would "
            "have no edge server"
        )
    rng = np.random.default_rng(0 if key is None else key)
    model_bits = tuple(
        float(v) for v in rng.uniform(90.0, 250.0, num_models) * MB_TO_BITS
    )
    sigma = tuple(float(v) for v in rng.uniform(0.8, 1.2, num_models))
    deadline = (5.0,) * num_models
    return EnvParams(
        num_eds=num_eds,
        num_ess=num_ess,
        num_models=num_models,
        cache_slots=2,
        f_cc=40e9,
        f_es=7e9,
        f_ed_lo=1e9,
        f_ed_hi=3e9,
        task_mb_lo=2.0,
        task_mb_hi=20.0,
        rho_lo=20.0,
        rho_hi=100.0,
        model_bits=model_bits,
        sigma=sigma,
        deadline=deadline,
        bandwidth_hz=20e6,
        noise_w_per_hz=3.98e-21,  # -174 dBm/Hz
        tx_power_w=0.5,
        pathloss_ref=1e-3,
        pathloss_exp=3.0,
        backhaul_bps=1e9,
        backhaul_power_w=2.0,
        kappa_ed=1e-28,
        kappa_es=1e-29,
        w_latency=0.5,
        w_energy=0.5,
        latency_scale=2.5,
        energy_scale=5.0,
        penalty=2.0,
        area_m=1000.0,
        episode_len=40,
        faithful=faithful,
        num_cells=num_cells,
    )


def es_cell(p: EnvParams) -> jnp.ndarray:
    """(N,) cell id per edge server — round-robin over ``num_cells``."""
    return jnp.arange(p.num_ess, dtype=jnp.int32) % p.num_cells


def ed_cell(p: EnvParams) -> jnp.ndarray:
    """(M,) cell id per edge device — round-robin over ``num_cells``."""
    return jnp.arange(p.num_eds, dtype=jnp.int32) % p.num_cells


def lru_keep(cache_row, last_row, slots: int):
    """Keep the ``slots`` most-recently-used cached models of one server.

    ``cache_row``: (K,) 0/1 residency; ``last_row``: (K,) last-use clocks.
    Shared by ``step`` and the batched router's eviction tests."""
    order = jnp.argsort(
        jnp.where(cache_row > 0.5, -last_row.astype(jnp.float32), jnp.inf)
    )
    keep_mask = jnp.zeros_like(cache_row).at[order[:slots]].set(1.0)
    return cache_row * keep_mask


def fifo_load(es_idx, offloaded, num_ess: int):
    """Per-agent FIFO-fair contention divisor (eqs. 6/9).

    Counts how many agents offload to each ES and returns, for every agent,
    the head-count at its chosen ES (>= 1 so non-offloaders divide by 1)."""
    load = jnp.zeros((num_ess,)).at[es_idx].add(offloaded.astype(jnp.float32))
    return jnp.maximum(load[es_idx], 1.0)


def _sample_tasks(key, p: EnvParams) -> Task:
    k1, k2, k3 = jax.random.split(key, 3)
    mu = jax.random.randint(k1, (p.num_eds,), 0, p.num_models)
    x = (
        jax.random.uniform(k2, (p.num_eds,), minval=p.task_mb_lo, maxval=p.task_mb_hi)
        * MB_TO_BITS
    )
    rho = jax.random.uniform(k3, (p.num_eds,), minval=p.rho_lo, maxval=p.rho_hi)
    return Task(mu=mu, x_bits=x, rho=rho)


def _init_cache(key, p: EnvParams) -> jnp.ndarray:
    """Each ES starts with ``cache_slots`` distinct random models."""
    keys = jax.random.split(key, p.num_ess)

    def one(k):
        perm = jax.random.permutation(k, p.num_models)
        slots = perm[: p.cache_slots]
        return jnp.zeros((p.num_models,), jnp.float32).at[slots].set(1.0)

    return jax.vmap(one)(keys)


def reset(key, p: EnvParams) -> EnvState:
    k_ed, k_es, k_f, k_cache, k_task, k_next = jax.random.split(key, 6)
    ed_pos = jax.random.uniform(k_ed, (p.num_eds, 2), maxval=p.area_m)
    es_pos = jax.random.uniform(k_es, (p.num_ess, 2), maxval=p.area_m)
    cc_pos = jnp.array([0.0, 0.0])
    f_ed = jax.random.uniform(k_f, (p.num_eds,), minval=p.f_ed_lo, maxval=p.f_ed_hi)
    cache = _init_cache(k_cache, p)
    return EnvState(
        key=k_next,
        t=jnp.int32(0),
        ed_pos=ed_pos,
        es_pos=es_pos,
        cc_pos=cc_pos,
        f_ed=f_ed,
        cache=cache,
        last_use=jnp.zeros((p.num_ess, p.num_models), jnp.int32),
        task=_sample_tasks(k_task, p),
    )


def observe(state: EnvState, p: EnvParams) -> jnp.ndarray:
    """Per-agent observation, paper eq. (16). Shape (M, obs_dim)."""
    m, n, k = p.num_eds, p.num_ess, p.num_models
    type_onehot = jax.nn.one_hot(state.task.mu, k)
    x_n = state.task.x_bits[:, None] / (p.task_mb_hi * MB_TO_BITS)
    rho_n = state.task.rho[:, None] / p.rho_hi
    f_es = jnp.broadcast_to(
        jnp.full((n,), p.f_es / p.f_cc, jnp.float32)[None, :], (m, n)
    )
    # d_{m,i,n}: does ES n hold the model this agent's task needs?
    # (masked to the agent's own cell — out-of-cell ESs are unreachable)
    compat = state.cache[:, state.task.mu].T  # (M, N)
    compat = compat * (es_cell(p)[None, :] == ed_cell(p)[:, None])
    own_pos = state.ed_pos / p.area_m
    es_pos = jnp.broadcast_to(
        (state.es_pos / p.area_m).reshape(-1)[None, :], (m, 2 * n)
    )
    cc_pos = jnp.broadcast_to((state.cc_pos / p.area_m)[None, :], (m, 2))
    f_ed = state.f_ed[:, None] / p.f_ed_hi
    return jnp.concatenate(
        [type_onehot, x_n, rho_n, f_es, compat, own_pos, es_pos, cc_pos, f_ed],
        axis=-1,
    )


def obs_dim(p: EnvParams) -> int:
    return p.num_models + 2 + p.num_ess + p.num_ess + 2 + 2 * p.num_ess + 2 + 1


def global_state(state: EnvState, p: EnvParams) -> jnp.ndarray:
    """Centralised-critic extras: full cache residency map."""
    return state.cache.reshape(-1)


def global_dim(p: EnvParams) -> int:
    return p.num_ess * p.num_models


def step(state: EnvState, act: Action, p: EnvParams):
    """Advance one scheduling slot. Returns (next_state, obs, outcome, done)."""
    m, n = p.num_eds, p.num_ess

    offloaded = (act.target > 0) & (act.eta > 1e-3)
    eta = jnp.where(offloaded, act.eta, 0.0)
    es_idx = jnp.clip(act.target - 1, 0, n - 1)  # valid only where offloaded

    # --- contention: uplink bandwidth + ES cycles are shared FIFO-fairly ----
    load_m = fifo_load(es_idx, offloaded, n)  # per-agent load at chosen ES

    dist = jnp.linalg.norm(state.ed_pos - state.es_pos[es_idx], axis=-1)
    gain = costs.channel_gain(dist, p.pathloss_ref, p.pathloss_exp)
    rate = costs.shannon_rate(
        p.bandwidth_hz / load_m, p.tx_power_w, gain, p.noise_w_per_hz
    )
    f_share = p.f_es / load_m

    # --- model residency / switching (eqs. 7-8) -----------------------------
    need = state.task.mu  # model index == task type
    cached = state.cache[es_idx, need]  # (M,)
    # cell feasibility: offloading to an out-of-cell ES cannot succeed
    # (num_cells == 1 makes in_cell all-True, reproducing the paper setting)
    in_cell = es_cell(p)[es_idx] == ed_cell(p)
    wants_download = offloaded & in_cell & (cached < 0.5) & (act.beta > 0.5)
    failed_compat = offloaded & (
        ~in_cell | ((cached < 0.5) & (act.beta <= 0.5))
    )

    model_bits = jnp.asarray(p.model_bits)[need]
    t_switch = jnp.where(
        wants_download, costs.switch_latency(model_bits, p.backhaul_bps), 0.0
    )
    e_switch = jnp.where(
        wants_download, costs.switch_energy(p.backhaul_power_w, t_switch), 0.0
    )

    # --- latency / energy (eqs. 3-12) ----------------------------------------
    x, rho = state.task.x_bits, state.task.rho
    t_local = costs.local_latency(x, eta, rho, state.f_ed)
    if p.faithful:
        e_local = costs.local_energy_faithful(x, eta, rho, p.kappa_ed, state.f_ed)
    else:
        e_local = costs.local_energy_corrected(x, eta, rho, p.kappa_ed, state.f_ed)

    t_trans = jnp.where(offloaded, costs.trans_latency(x, eta, rate), 0.0)
    e_trans = costs.trans_energy(p.tx_power_w, t_trans)
    t_comp = jnp.where(offloaded, costs.edge_latency(x, eta, rho, f_share), 0.0)
    if p.faithful:
        e_comp = jnp.where(
            offloaded, costs.edge_energy_faithful(x, eta, rho, p.kappa_es, p.f_es), 0.0
        )
    else:
        e_comp = jnp.where(
            offloaded, costs.edge_energy_corrected(x, eta, rho, p.kappa_es, p.f_es), 0.0
        )

    t_edge = costs.edge_total_latency(t_trans, t_switch, t_comp)
    e_edge = costs.edge_total_energy(e_trans, e_switch, e_comp)

    latency = costs.total_latency(t_local, t_edge)
    energy = costs.total_energy(e_local, e_edge, p.faithful)

    # --- completion ----------------------------------------------------------
    deadline = jnp.asarray(p.deadline)[need]
    completed = ((latency <= deadline) & ~failed_compat).astype(jnp.float32)

    # --- reward (eq. 18 inner term, normalised for learning stability) -------
    sig = jnp.asarray(p.sigma)[need]
    reward = -sig * (
        p.w_latency * latency / p.latency_scale
        + p.w_energy * energy / p.energy_scale
    ) - p.penalty * (
        failed_compat.astype(jnp.float32)
        + (latency > deadline).astype(jnp.float32)
    )

    # --- cache transition with LRU eviction ----------------------------------
    hit = offloaded & in_cell & (cached > 0.5)
    use_inc = (
        jnp.zeros((n, p.num_models))
        .at[es_idx, need]
        .add((hit | wants_download).astype(jnp.float32))
    )
    new_last_use = jnp.where(use_inc > 0, state.t + 1, state.last_use)

    added = (
        jnp.zeros((n, p.num_models))
        .at[es_idx, need]
        .max(wants_download.astype(jnp.float32))
    )
    cache = jnp.maximum(state.cache, added)

    # evict LRU entries beyond capacity (vectorised top-k keep per ES)
    cache = jax.vmap(lambda c, l: lru_keep(c, l, p.cache_slots))(
        cache, new_last_use
    )

    k_task, k_next = jax.random.split(state.key)
    t_next = state.t + 1
    done = t_next >= p.episode_len

    next_state = EnvState(
        key=k_next,
        t=t_next,
        ed_pos=state.ed_pos,
        es_pos=state.es_pos,
        cc_pos=state.cc_pos,
        f_ed=state.f_ed,
        cache=cache,
        last_use=new_last_use,
        task=_sample_tasks(k_task, p),
    )
    outcome = StepOutcome(
        latency=latency,
        energy=energy,
        completed=completed,
        failed_compat=failed_compat.astype(jnp.float32),
        reward=reward,
        switch_latency=t_switch,
    )
    return next_state, observe(next_state, p), outcome, done


def auto_reset(state: EnvState, done, p: EnvParams) -> EnvState:
    """Fold a reset into the scan when the episode ends."""
    fresh = reset(state.key, p)
    return jax.tree.map(lambda a, b: jnp.where(done, b, a), state, fresh)
