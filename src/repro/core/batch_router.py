"""Batched fleet-scale request router — the paper's technique, jitted.

``core.router.ModelAwareRouter`` routes ONE request at a time through
Python dataclass mutation; it stays as the readable reference oracle.
This module is the production path: a whole batch of tagged generation
requests is dispatched across the server fleet in ONE jitted call.

Design
------
* **Array-resident fleet state** (``FleetState``): residency masks and
  LRU clocks as ``(N, K)`` arrays, queue depths as ``(N,)`` — no Python
  objects survive into the hot path.
* **Vectorised scoring kernel** (``score_matrix``): the paper's cost
  terms — transmission (eq. 5), model switch (eq. 7), FIFO-fair compute
  (eq. 9) — evaluated for ALL request x server pairs at once as a
  ``(B, N)`` matrix, sharing ``core.costs`` with the environment.
* **Sequential-commit semantics** (``route_batch``): requests within a
  batch still contend for queues and caches, so commits are applied in
  arrival order by a ``lax.scan`` whose per-step work is vectorised over
  the fleet. The request-independent cost terms (transmission, switch
  price) come from the precomputed matrix; only the state-dependent
  residency gate and queue backlog are evaluated inside the scan. This
  reproduces the scalar router *exactly* — including LRU tie-breaking,
  which is preserved by encoding each initial resident's list position
  as a distinct negative clock (the scalar oracle breaks last-use ties
  by list order).
* **Pluggable policies**: ``greedy`` (argmin of the eq. 11 latency),
  ``actor`` (a trained MADDPG actor called with the same observation
  layout the scalar router exposes), ``load`` (least-loaded server,
  switch-blind — a fleet-level baseline).

Multi-cell fleets
-----------------
Servers carry a ``cell`` id (``FleetParams.cell``) and requests a
``RequestBatch.cell``; the score matrix is masked block-diagonally so a
request only sees the servers of its own cell, plus every server in the
reserved ``CLOUD_CELL`` (-1) — the cloud-fallback column, visible
fleet-wide and priced through the backhaul (its effective uplink folds
the extra hop; see ``launch.serve.make_cloud_server``). One jitted
``route_batch`` call therefore routes an entire multi-cell fleet:
C cells x N servers x B requests, no per-cell Python loop. When
``RequestBatch.cell`` is ``None`` (the default) the mask is compiled
out entirely and the fleet behaves as one cell.

Time-based drain
----------------
Servers complete queued work continuously at ``FleetParams.drain_rate``
tokens/sec. Requests carry a wall-clock ``RequestBatch.arrival_s``; the
scan carry holds the fleet clock ``FleetState.time_s``, and before each
request is scored every queue decays by ``drain_rate * dt`` with ``dt``
the time elapsed since the carry clock last advanced. Queue decay thus
tracks wall clock rather than request count. ``drain_rate == 0`` (or
``arrival_s=None``) reproduces the synchronous behaviour exactly; the
legacy per-request ``drain_tokens`` argument is still honoured.

Follow-ons tracked in ROADMAP: a Pallas scoring kernel once N x K
residency rows stop fitting VMEM-friendly tiles, and trained-actor
serving through ``launch/serve.py``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.router import CLOUD_CELL

_NEVER_USED = -(2**30)  # last-use clock for models that are not resident


class FleetParams(NamedTuple):
    """Static per-server capabilities + per-model catalogue columns."""

    flops_per_s: jnp.ndarray          # (N,)
    uplink_bps: jnp.ndarray           # (N,)
    backhaul_bps: jnp.ndarray         # (N,)
    cache_slots: jnp.ndarray          # (N,) int32
    size_bits: jnp.ndarray            # (K,) model weights over the backhaul
    decode_flops_per_token: jnp.ndarray  # (K,)
    cell: Optional[jnp.ndarray] = None        # (N,) int32 cell id; CLOUD_CELL
    drain_rate: Optional[jnp.ndarray] = None  # (N,) tokens/sec drained


class FleetState(NamedTuple):
    """Mutable routing state, one array per concern."""

    resident: jnp.ndarray    # (N, K) bool residency mask
    last_use: jnp.ndarray    # (N, K) int32 LRU clocks
    queue_tokens: jnp.ndarray  # (N,) outstanding decode work, FIFO
    clock: jnp.ndarray       # () int32, increments per routed request
    time_s: Optional[jnp.ndarray] = None  # () wall clock for the time drain


class RequestBatch(NamedTuple):
    """A batch of tagged generation requests (struct-of-arrays).

    ``cell``/``arrival_s`` are optional topology/timing columns: ``None``
    (the default) statically compiles the cell mask / time drain out of
    the scan, preserving the single-cell synchronous fast path.
    """

    model: jnp.ndarray        # (B,) int32 catalogue index
    prompt_bits: jnp.ndarray  # (B,)
    gen_tokens: jnp.ndarray   # (B,)
    cell: Optional[jnp.ndarray] = None       # (B,) int32 requesting cell
    arrival_s: Optional[jnp.ndarray] = None  # (B,) wall-clock arrivals


class RouteOutcome(NamedTuple):
    choice: jnp.ndarray     # (B,) int32 chosen server; -1 == rejected
    latency: jnp.ndarray    # (B,) predicted eq. 11 latency at choice
    hit: jnp.ndarray        # (B,) bool — model resident at decision time


# ---------------------------------------------------------------------------
# fleet construction
# ---------------------------------------------------------------------------
def make_fleet_params(servers, catalog) -> FleetParams:
    """Build array fleet params from ``EdgeServer``s + ``CatalogEntry``s."""
    import numpy as np

    entries = sorted(catalog, key=lambda e: e.index)
    return FleetParams(
        flops_per_s=jnp.asarray(np.array([s.flops_per_s for s in servers])),
        uplink_bps=jnp.asarray(np.array([s.uplink_bps for s in servers])),
        backhaul_bps=jnp.asarray(np.array([s.backhaul_bps for s in servers])),
        cache_slots=jnp.asarray(
            np.array([s.cache_slots for s in servers], np.int32)
        ),
        size_bits=jnp.asarray(np.array([e.size_bits for e in entries])),
        decode_flops_per_token=jnp.asarray(
            np.array([e.decode_flops_per_token for e in entries])
        ),
        cell=jnp.asarray(
            np.array([getattr(s, "cell", 0) for s in servers], np.int32)
        ),
        drain_rate=jnp.asarray(
            np.array([getattr(s, "drain_rate", 0.0) for s in servers])
        ),
    )


def make_fleet_state(servers, num_models: int, clock: int = 0,
                     time_s: float = 0.0) -> FleetState:
    """Array state mirroring the scalar servers' residency/queues.

    The scalar oracle breaks LRU ties (several never-used residents, all
    ``last_use == -1``) by position in the ``resident`` list; we encode
    position ``i`` of a list of length L as clock ``i - L`` so ties become
    a strict order that an argmin resolves identically."""
    import numpy as np

    n = len(servers)
    resident = np.zeros((n, num_models), bool)
    last_use = np.full((n, num_models), _NEVER_USED, np.int32)
    for si, s in enumerate(servers):
        for pos, m in enumerate(s.resident):
            resident[si, m] = True
            last_use[si, m] = s.last_use.get(m, pos - len(s.resident))
        for m, t in s.last_use.items():
            last_use[si, m] = t
    queue = np.array([s.queue_tokens for s in servers])
    return FleetState(
        resident=jnp.asarray(resident),
        last_use=jnp.asarray(last_use),
        queue_tokens=jnp.asarray(queue),
        clock=jnp.asarray(clock, jnp.int32),
        time_s=jnp.asarray(time_s, jnp.asarray(queue).dtype),
    )


def fleet_from_servers(servers, catalog, clock: int = 0, time_s: float = 0.0):
    """(FleetParams, FleetState) snapshot of a scalar router's fleet.

    ``clock`` must be the scalar router's current clock when snapshotting
    mid-stream (its ``last_use`` values are in [1, clock]; starting the
    batched clock below them would invert LRU order). Fresh fleets use 0.
    ``time_s`` likewise carries the oracle's wall clock (``router.time_s``)
    so the time-based drain resumes from the same instant.
    """
    return (
        make_fleet_params(servers, catalog),
        make_fleet_state(servers, len(catalog), clock=clock, time_s=time_s),
    )


# ---------------------------------------------------------------------------
# vectorised scoring
# ---------------------------------------------------------------------------
def _static_costs(params: FleetParams, reqs: RequestBatch):
    """State-independent pieces of the eq. 11 score, one shot per batch:
    eq. 5 transmission (B, N), eq. 7 switch price (B, N) before the
    residency gate, and per-request decode FLOPs/token (B,)."""
    t_trans = costs.trans_latency(
        reqs.prompt_bits[:, None], 1.0, params.uplink_bps[None, :]
    )
    switch_price = costs.switch_latency(
        params.size_bits[reqs.model][:, None], params.backhaul_bps[None, :]
    )
    flops_tok = params.decode_flops_per_token[reqs.model]
    return t_trans, switch_price, flops_tok


def cell_mask(params: FleetParams, reqs: RequestBatch):
    """(B, N) block-diagonal visibility mask, or ``None`` when untopologied.

    True where the server is in the request's cell OR in the reserved
    ``CLOUD_CELL`` (the fleet-wide cloud-fallback column). ``None`` —
    returned when either side carries no cell ids — means "everything
    visible" and lets callers compile the mask away statically."""
    if params.cell is None or reqs.cell is None:
        return None
    return (params.cell[None, :] == reqs.cell[:, None]) | (
        params.cell[None, :] == CLOUD_CELL
    )


def score_matrix(params: FleetParams, state: FleetState, reqs: RequestBatch):
    """Full (B, N) eq. 11 cost matrix against the CURRENT fleet state.

    One shot over all request x server pairs: eq. 5 transmission +
    eq. 7 switch (gated on residency) + eq. 9 compute against the
    present queue backlog. Out-of-cell pairs score ``+inf`` when the
    batch carries cell ids (block-diagonal mask + cloud column).
    ``route_batch`` shares the state-independent pieces
    (``_static_costs``) and re-derives the state-dependent ones step by
    step; this entry point is the one-shot view (policy studies,
    admission control, and the planned Pallas kernel target exactly this
    contraction)."""
    t_trans, switch_price, flops_tok = _static_costs(params, reqs)
    resident = state.resident[:, reqs.model].T            # (B, N)
    t_switch = jnp.where(resident, 0.0, switch_price)
    backlog = state.queue_tokens[None, :] * flops_tok[:, None]
    work = (reqs.gen_tokens * flops_tok)[:, None]
    t_comp = (backlog + work) / params.flops_per_s[None, :]
    score = t_trans + t_switch + t_comp
    visible = cell_mask(params, reqs)
    if visible is not None:
        score = jnp.where(visible, score, jnp.inf)
    return score


# ---------------------------------------------------------------------------
# policies: (latencies (N,), obs (3N,), queue (N,)) -> server index
# ---------------------------------------------------------------------------
def _greedy_policy(lats, obs, queue):
    return jnp.argmin(lats)


def _load_policy(lats, obs, queue):
    return jnp.argmin(queue)


_greedy_policy.needs_obs = False
_load_policy.needs_obs = False


def _make_actor_policy(actor: Callable[[Any, Any], Any]):
    def policy(lats, obs, queue):
        return jnp.asarray(actor(obs, lats), jnp.int32)

    policy.needs_obs = True
    return policy


def _resolve_policy(policy, actor):
    if callable(policy):
        return policy
    if policy == "greedy":
        return _greedy_policy
    if policy == "load":
        return _load_policy
    if policy == "actor":
        if actor is None:
            raise ValueError("policy='actor' requires an actor callable")
        return _make_actor_policy(actor)
    raise ValueError(f"unknown policy {policy!r}")


# ---------------------------------------------------------------------------
# batched routing with sequential-commit semantics
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("policy", "actor"))
def route_batch(
    params: FleetParams,
    state: FleetState,
    reqs: RequestBatch,
    drain_tokens=None,
    *,
    policy="greedy",
    actor=None,
):
    """Route a whole request batch in one call; returns (state, outcome).

    Requests commit in arrival order (queue growth, LRU insert/evict)
    exactly like B sequential ``ModelAwareRouter.route`` calls, each
    followed by ``drain(drain_tokens)`` (scalar or (B,); None — the
    default — skips the drain update entirely in the compiled scan).

    Cell/drain knobs (both compiled out of the scan when absent):
      * ``reqs.cell`` + ``params.cell`` — block-diagonal visibility:
        each request scores ``+inf`` on out-of-cell servers, with
        ``CLOUD_CELL`` servers visible fleet-wide, so one call routes a
        whole multi-cell fleet.
      * ``reqs.arrival_s`` + ``params.drain_rate`` — time-based drain:
        before a request is scored, every queue decays by
        ``drain_rate * dt`` where ``dt`` is the wall-clock gap since the
        carry clock ``state.time_s`` last advanced.
    """
    policy_fn = _resolve_policy(policy, actor)
    dtype = jnp.result_type(reqs.prompt_bits, params.uplink_bps)

    # state-independent cost pieces, vectorised over the full batch
    t_trans, switch_price, flops_tok = _static_costs(params, reqs)
    gen_tokens = reqs.gen_tokens.astype(dtype)                  # (B,)
    work = gen_tokens * flops_tok                               # (B,)
    drain = (
        None
        if drain_tokens is None
        else jnp.broadcast_to(jnp.asarray(drain_tokens, dtype),
                              reqs.model.shape)
    )
    has_cells = params.cell is not None and reqs.cell is not None
    has_time = params.drain_rate is not None and reqs.arrival_s is not None
    drain_rate = params.drain_rate.astype(dtype) if has_time else None
    arrivals = reqs.arrival_s.astype(dtype) if has_time else None
    time0 = state.time_s if state.time_s is not None else 0.0
    queue0 = state.queue_tokens.astype(dtype)

    def step(carry, xs):
        resident, last_use, queue, clock, time_s = carry
        (model, t_trans_b, switch_b, flops_tok_b, work_b, drain_b, gen_b,
         cell_b, arrival_b) = xs

        if has_time:  # wall-clock queue decay since the last arrival
            dt = jnp.maximum(arrival_b - time_s, 0.0)
            queue = jnp.maximum(queue - drain_rate * dt, 0.0)
            time_s = jnp.maximum(time_s, arrival_b)
        clock = clock + 1

        resident_m = resident[:, model]                         # (N,)
        t_switch = jnp.where(resident_m, 0.0, switch_b)
        t_comp = (queue * flops_tok_b + work_b) / params.flops_per_s
        lats = t_trans_b + t_switch + t_comp                    # eq. 11
        queue_vis = queue
        if has_cells:  # out-of-cell servers can never win the argmin
            visible = (params.cell == cell_b) | (params.cell == CLOUD_CELL)
            lats = jnp.where(visible, lats, jnp.inf)
            queue_vis = jnp.where(visible, queue, jnp.inf)

        if getattr(policy_fn, "needs_obs", True):
            # scalar _observe layout: [resident, queue, flops] per server
            obs = jnp.stack(
                [resident_m.astype(dtype), queue, params.flops_per_s], axis=-1
            ).reshape(-1)                                       # (3N,)
        else:
            obs = None
        choice = jnp.asarray(policy_fn(lats, obs, queue_vis), jnp.int32)
        if has_cells:
            # an actor may ignore the inf-masked inputs; never commit an
            # out-of-cell choice — fall back to the masked greedy argmin
            choice = jnp.where(visible[choice], choice,
                               jnp.argmin(lats).astype(jnp.int32))

        # commit: LRU residency + queue, mirroring the scalar oracle
        row = resident[choice]
        was_resident = row[model]
        full = row.sum() >= params.cache_slots[choice]
        evict_idx = jnp.argmin(
            jnp.where(row, last_use[choice], jnp.iinfo(jnp.int32).max)
        )
        evict = ~was_resident & full
        if has_cells:
            # a cell with no members and no cloud column leaves every
            # candidate at inf: reject (choice -1) without committing
            ok = jnp.isfinite(lats[choice])
            evict &= ok
            row = row.at[evict_idx].set(row[evict_idx] & ~evict)
            row = row.at[model].set(row[model] | ok)
            resident = resident.at[choice].set(row)
            last_use = last_use.at[choice, model].set(
                jnp.where(ok, clock, last_use[choice, model])
            )
            queue = queue.at[choice].add(jnp.where(ok, gen_b, 0.0))
            out = (jnp.where(ok, choice, -1), lats[choice],
                   was_resident & ok)
        else:
            row = row.at[evict_idx].set(row[evict_idx] & ~evict)
            row = row.at[model].set(True)
            resident = resident.at[choice].set(row)
            last_use = last_use.at[choice, model].set(clock)
            queue = queue.at[choice].add(gen_b)
            out = (choice, lats[choice], was_resident)
        if drain_b is not None:  # None is static: compiled out of the scan
            queue = jnp.maximum(queue - drain_b, 0.0)
        return (resident, last_use, queue, clock, time_s), out

    carry = (state.resident, state.last_use, queue0, state.clock,
             jnp.asarray(time0, dtype))
    xs = (reqs.model, t_trans, switch_price, flops_tok, work, drain,
          gen_tokens, reqs.cell if has_cells else None, arrivals)
    ((resident, last_use, queue, clock, time_s),
     (choice, latency, hit)) = jax.lax.scan(step, carry, xs, unroll=8)
    new_state = FleetState(
        resident=resident, last_use=last_use, queue_tokens=queue, clock=clock,
        time_s=time_s,
    )
    return new_state, RouteOutcome(choice=choice, latency=latency, hit=hit)


def stats(outcome: RouteOutcome) -> dict:
    """Fleet-level summary of one routed batch."""
    return {
        "mean_latency": float(outcome.latency.mean()),
        "residency_hit_rate": float(outcome.hit.mean()),
    }
