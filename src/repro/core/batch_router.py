"""Batched fleet-scale request router — the paper's technique, jitted.

``core.router.ModelAwareRouter`` routes ONE request at a time through
Python dataclass mutation; it stays as the readable reference oracle.
This module is the production path: a whole batch of tagged generation
requests is dispatched across the server fleet in ONE jitted call.

Design
------
* **Array-resident fleet state** (``FleetState``): residency masks and
  LRU clocks as ``(N, K)`` arrays, queue depths as ``(N,)`` — no Python
  objects survive into the hot path.
* **Fused scoring kernel** (``score_matrix``): the paper's cost terms —
  transmission (eq. 5), model switch (eq. 7), FIFO-fair compute (eq. 9)
  — evaluated for ALL request x server pairs at once as a ``(B, N)``
  matrix. The arithmetic lives in ``core.costs.edge_score_matrix``; the
  contraction dispatches through ``kernels.ops.route_score`` to either
  the XLA reference (``backend="xla"``) or the tiled Pallas kernel
  (``kernels/route_score.py``, ``backend="pallas"`` /
  ``"pallas-interpret"``). ``backend=None`` reads the
  ``REPRO_ROUTER_BACKEND`` env knob (default ``"xla"``).
* **Sequential-commit semantics** (``route_batch``): requests within a
  batch still contend for queues and caches, so commits are applied in
  arrival order by a ``lax.scan`` whose per-step work is vectorised over
  the fleet. This reproduces the scalar router *exactly* — including
  LRU tie-breaking, which is preserved by encoding each initial
  resident's list position as a distinct negative clock (the scalar
  oracle breaks last-use ties by list order).
* **Chunked two-phase commit** (``route_batch(..., chunk=c)``): the
  serial region shrinks from B full scoring steps to B cheap correction
  steps. Phase 1 scores a whole chunk of ``c`` requests with one fused
  kernel call — the *switch-free base* ``t_trans + work/flops`` plus
  the cell mask, all state-independent. Phase 2 is a slimmed scan that
  only re-derives the state-dependent residue per step, from two
  per-request SCALARS (the model's size and FLOPs/token) against
  per-server constants:

      lats = base + where(resident[:, model], 0, size/backhaul)
                  + (queue * flops_tok)/flops

  i.e. the residency gate, the queue-backlog drift and the wall-clock
  drain — one fused elementwise chain; no transmission term, no cell
  compare, and no per-step (B, N) rows beyond the base left in the
  serial region. Integer decisions (choices, LRU
  evictions, residency, queues, fleet clock) stay bit-identical to the
  scalar oracle; reported latencies agree to a few ulps (the re-
  association of eq. 9 — ``q*ftok/f + w/f`` vs ``(q*ftok + w)/f`` —
  rounds differently). ``chunk=None`` (default) keeps the single-scan
  path whose latencies are bit-exact against the oracle.
* **Speculative parallel commit** (``route_batch(..., chunk=c)``, the
  default ``speculative=True``, greedy policy): phase 1 prices the whole
  chunk against the CHUNK-ENTRY residency (the fused kernel call gains
  the residency gate), so each request's provisional argmin depends on
  the fleet state only through the queue vector. A commit can invalidate
  a later provisional decision only by CHANGING a score it read —
  queue growth is carried exactly by a slimmed scan whose whole body is
  ``argmin(base + queue*qcoef)`` plus one masked add, and the only
  residency-mutating commits are misses (installs/evictions). Every
  decision up to the first committed miss is therefore the oracle
  decision; their LRU bookkeeping (hits only touch last-use clocks,
  which no score reads) is applied in ONE vectorised scatter, and the
  conflicting suffix from the first miss onward is replayed serially
  with the full correction body. Steady-state serving (hit rate near 1)
  commits whole chunks speculatively; cold caches degrade gracefully to
  the serial correction scan. Decisions and fleet state remain
  bit-identical to the scalar oracle; ``speculative=False`` forces the
  plain correction scan (the A/B baseline ``benchmarks/
  router_throughput.py`` records).
* **Pluggable policies**: ``greedy`` (argmin of the eq. 11 latency),
  ``drain`` (drain-aware greedy: the queue backlog is discounted by the
  server's ``drain_rate`` before eq. 9 pricing), ``actor`` (a trained
  MADDPG actor called with the same observation layout the scalar router
  exposes — restored checkpoints plug in via ``core.policies``),
  ``load`` (least-loaded server, switch-blind — a fleet-level baseline).

Policy dispatch contract
------------------------
A policy is any traceable callable ``policy_fn(lats, obs, queue) ->
server index`` evaluated once per request inside the routing scan:

* ``lats``  — (N,) eq. 11 latencies against the CURRENT fleet state,
  ``+inf`` on servers outside the request's cell;
* ``obs``   — (3N,) scalar-router observation (``[resident, queue,
  flops]`` per server), or ``None`` if the policy sets ``needs_obs =
  False`` (saves building it in the compiled scan);
* ``queue`` — (N,) queue depths, ``+inf``-masked like ``lats``.

Two opt-in attributes refine the contract:

* ``needs_obs`` (default True) — set False to skip the obs build;
* ``needs_ctx`` (default False) — set True to be called as
  ``policy_fn(lats, obs, queue, ctx)`` with a per-request ``PolicyCtx``
  (fleet params, tagged model, prompt/gen scalars, raw queues, the
  model's residency row and the request cell). ``core.policies`` builds
  the trained-actor policy on exactly this hook.

Chunk-level hook (the batched-actor fast path): a ``needs_ctx`` policy
may additionally define the attribute pair

* ``chunk_precompute(cctx: ChunkPolicyCtx) -> aux`` — called once per
  chunk (chunked path only) with the whole chunk's request columns and
  the CHUNK-ENTRY residency; returns any pytree of ``(c, ...)`` arrays
  (e.g. MLP decisions batched over the chunk on the MXU);
* ``chunk_apply(aux_b, ctx) -> (server index, exact)`` — called per
  step instead of ``policy_fn`` with that request's ``aux`` slice and
  the live ``PolicyCtx``; it resolves the precomputed table against the
  live state and FLAGS (rather than repairs) drift: ``exact=False``
  on any step makes the router rerun the whole chunk through the plain
  per-request path (one ``lax.cond`` per chunk — a per-step cond would
  tax every iteration of the compiled scan with the expensive branch's
  captured operands, even when never taken).

``core.policies.make_actor_policy`` uses exactly this pair: the MLP is
priced per chunk over the entry compat row plus every single-bit flip
(a radius-1 Hamming-ball table), ``chunk_apply`` is a branch-free table
lookup, and multi-bit residency drift — unobserved in steady serving —
falls back to the exact whole-chunk replay.

Whatever the policy returns is clamped to the request's cell (an
out-of-cell choice falls back to the masked greedy argmin) and committed
with full LRU/queue semantics; out-of-range indices — which a JAX gather
would silently clamp to server N-1 — fall back the same way even on
untopologied fleets, so a policy can never corrupt the fleet state, only
pick worse servers.

Multi-cell fleets
-----------------
Servers carry a ``cell`` id (``FleetParams.cell``) and requests a
``RequestBatch.cell``; the score matrix is masked block-diagonally so a
request only sees the servers of its own cell, plus every server in the
reserved ``CLOUD_CELL`` (-1) — the cloud-fallback column, visible
fleet-wide and priced through the backhaul (its effective uplink folds
the extra hop; see ``launch.serve.make_cloud_server``). One jitted
``route_batch`` call therefore routes an entire multi-cell fleet:
C cells x N servers x B requests, no per-cell Python loop. When
``RequestBatch.cell`` is ``None`` (the default) the mask is compiled
out entirely and the fleet behaves as one cell.

Time-based drain
----------------
Servers complete queued work continuously at ``FleetParams.drain_rate``
tokens/sec. Requests carry a wall-clock ``RequestBatch.arrival_s``; the
scan carry holds the fleet clock ``FleetState.time_s``, and before each
request is scored every queue decays by ``drain_rate * dt`` with ``dt``
the time elapsed since the carry clock last advanced. Queue decay thus
tracks wall clock rather than request count. ``drain_rate == 0`` (or
``arrival_s=None``) reproduces the synchronous behaviour exactly; the
legacy per-request ``drain_tokens`` argument is still honoured.

``launch/serve.py`` exposes all of this end to end (``--policy
{greedy,load,drain,actor:<ckpt>}``); ``docs/serving.md`` is the guide.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costs
from repro.core.router import (
    CAUSE_ADMISSION, CAUSE_COMPLETED, CAUSE_INFEASIBLE, CAUSE_OUTAGE,
    CLOUD_CELL,
)
from repro.kernels import ops

_NEVER_USED = -(2**30)  # last-use clock for models that are not resident

#: Env knob for the scoring backend: "xla" | "pallas" | "pallas-interpret".
BACKEND_ENV = "REPRO_ROUTER_BACKEND"
_BACKENDS = ("xla", "pallas", "pallas-interpret")


def resolve_backend(backend: Optional[str] = None) -> str:
    """``None`` -> ``$REPRO_ROUTER_BACKEND`` (default ``"xla"``)."""
    backend = backend or os.environ.get(BACKEND_ENV, "xla")
    if backend not in _BACKENDS:
        raise ValueError(
            f"unknown router backend {backend!r}; expected one of {_BACKENDS}"
        )
    return backend


class FleetParams(NamedTuple):
    """Static per-server capabilities + per-model catalogue columns."""

    flops_per_s: jnp.ndarray          # (N,)
    uplink_bps: jnp.ndarray           # (N,)
    backhaul_bps: jnp.ndarray         # (N,)
    cache_slots: jnp.ndarray          # (N,) int32
    size_bits: jnp.ndarray            # (K,) model weights over the backhaul
    decode_flops_per_token: jnp.ndarray  # (K,)
    cell: Optional[jnp.ndarray] = None        # (N,) int32 cell id; CLOUD_CELL
    drain_rate: Optional[jnp.ndarray] = None  # (N,) tokens/sec drained
    #: (C, C) bool neighbour-cell adjacency: ``spill[rc, sc]`` makes cell
    #: ``sc``'s servers visible to cell ``rc``'s requests at a backhaul
    #: surcharge (``prompt_bits / backhaul_bps`` — the prompt crosses the
    #: inter-cell link). ``None`` compiles the spill column out.
    spill: Optional[jnp.ndarray] = None


class FleetState(NamedTuple):
    """Mutable routing state, one array per concern."""

    resident: jnp.ndarray    # (N, K) bool residency mask
    last_use: jnp.ndarray    # (N, K) int32 LRU clocks
    queue_tokens: jnp.ndarray  # (N,) outstanding decode work, FIFO
    clock: jnp.ndarray       # () int32, increments per routed request
    time_s: Optional[jnp.ndarray] = None  # () wall clock for the time drain


class RequestBatch(NamedTuple):
    """A batch of tagged generation requests (struct-of-arrays).

    ``cell``/``arrival_s`` are optional topology/timing columns: ``None``
    (the default) statically compiles the cell mask / time drain out of
    the scan, preserving the single-cell synchronous fast path.
    """

    model: jnp.ndarray        # (B,) int32 catalogue index
    prompt_bits: jnp.ndarray  # (B,)
    gen_tokens: jnp.ndarray   # (B,)
    cell: Optional[jnp.ndarray] = None       # (B,) int32 requesting cell
    arrival_s: Optional[jnp.ndarray] = None  # (B,) wall-clock arrivals
    #: (B,) per-request SLO deadline in seconds. A request whose BEST
    #: eq. 11 score exceeds its deadline is rejected (admission control);
    #: ``+inf`` entries have no SLO, ``None`` compiles the check out.
    deadline_s: Optional[jnp.ndarray] = None
    #: (B,) eq. 16 offload ratio in [0, 1]: the edge side transmits and
    #: computes the ``eta`` fraction (eq. 5/9 scale), the device keeps
    #: ``1 - eta`` (eq. 3, priced via ``local_flops_per_s``), and the
    #: commit queues only ``eta * gen_tokens``. ``None`` compiles the
    #: knob out — bit-identical to pricing every request at eta = 1.
    eta: Optional[jnp.ndarray] = None
    #: (B,) eq. 16 download decision: ``False`` refuses the eq. 7 model
    #: fetch on a residency miss, so non-resident candidates price
    #: ``+inf`` and a committed request is always a hit. ``None`` (or
    #: ``True``) downloads on miss as before.
    beta: Optional[jnp.ndarray] = None
    #: (B,) requesting device's compute speed for the eq. 3 local share
    #: under partial offload; ``None`` (or entries <= 0) prices the
    #: local side at zero. Only read when ``eta`` is present.
    local_flops_per_s: Optional[jnp.ndarray] = None


class RouteOutcome(NamedTuple):
    choice: jnp.ndarray     # (B,) int32 chosen server; -1 == rejected
    latency: jnp.ndarray    # (B,) predicted eq. 11 latency at choice
    hit: jnp.ndarray        # (B,) bool — model resident at decision time
    #: (B,) int32 rejection cause: CAUSE_COMPLETED (0) for routed
    #: requests, else CAUSE_INFEASIBLE / CAUSE_ADMISSION / CAUSE_OUTAGE
    #: (see ``rejection_cause``). ``None`` only on hand-built outcomes.
    cause: Optional[jnp.ndarray] = None


# ---------------------------------------------------------------------------
# fleet construction
# ---------------------------------------------------------------------------
def make_fleet_params(servers, catalog, spill=None) -> FleetParams:
    """Build array fleet params from ``EdgeServer``s + ``CatalogEntry``s.

    ``spill`` — an optional (C, C) bool neighbour-cell adjacency — lands
    verbatim in ``FleetParams.spill`` (see the field doc)."""
    entries = sorted(catalog, key=lambda e: e.index)
    return FleetParams(
        spill=None if spill is None else jnp.asarray(np.asarray(spill, bool)),
        flops_per_s=jnp.asarray(np.array([s.flops_per_s for s in servers])),
        uplink_bps=jnp.asarray(np.array([s.uplink_bps for s in servers])),
        backhaul_bps=jnp.asarray(np.array([s.backhaul_bps for s in servers])),
        cache_slots=jnp.asarray(
            np.array([s.cache_slots for s in servers], np.int32)
        ),
        size_bits=jnp.asarray(np.array([e.size_bits for e in entries])),
        decode_flops_per_token=jnp.asarray(
            np.array([e.decode_flops_per_token for e in entries])
        ),
        cell=jnp.asarray(
            np.array([getattr(s, "cell", 0) for s in servers], np.int32)
        ),
        drain_rate=jnp.asarray(
            np.array([getattr(s, "drain_rate", 0.0) for s in servers])
        ),
    )


def make_fleet_state(servers, num_models: int, clock: int = 0,
                     time_s: float = 0.0) -> FleetState:
    """Array state mirroring the scalar servers' residency/queues.

    The scalar oracle breaks LRU ties (several never-used residents, all
    ``last_use == -1``) by position in the ``resident`` list; we encode
    position ``i`` of a list of length L as clock ``i - L`` so ties become
    a strict order that an argmin resolves identically."""
    n = len(servers)
    resident = np.zeros((n, num_models), bool)
    last_use = np.full((n, num_models), _NEVER_USED, np.int32)
    for si, s in enumerate(servers):
        for pos, m in enumerate(s.resident):
            resident[si, m] = True
            last_use[si, m] = s.last_use.get(m, pos - len(s.resident))
        for m, t in s.last_use.items():
            last_use[si, m] = t
    queue = np.array([s.queue_tokens for s in servers])
    return FleetState(
        resident=jnp.asarray(resident),
        last_use=jnp.asarray(last_use),
        queue_tokens=jnp.asarray(queue),
        clock=jnp.asarray(clock, jnp.int32),
        time_s=jnp.asarray(time_s, jnp.asarray(queue).dtype),
    )


def fleet_from_servers(servers, catalog, clock: int = 0, time_s: float = 0.0,
                       spill=None):
    """(FleetParams, FleetState) snapshot of a scalar router's fleet.

    ``clock`` must be the scalar router's current clock when snapshotting
    mid-stream (its ``last_use`` values are in [1, clock]; starting the
    batched clock below them would invert LRU order). Fresh fleets use 0.
    ``time_s`` likewise carries the oracle's wall clock (``router.time_s``)
    so the time-based drain resumes from the same instant. ``spill``
    mirrors the oracle's neighbour-cell adjacency.
    """
    return (
        make_fleet_params(servers, catalog, spill=spill),
        make_fleet_state(servers, len(catalog), clock=clock, time_s=time_s),
    )


# ---------------------------------------------------------------------------
# cell-major layout
# ---------------------------------------------------------------------------
class CellLayout(NamedTuple):
    """Block shape of a CELL-MAJOR fleet.

    The canonical multi-cell server ordering (what
    ``launch.serve.make_multicell_fleet`` produces): edge cells
    ``0..C-1`` laid out as equal-size contiguous server blocks, with
    every fleet-wide ``CLOUD_CELL`` column trailing. In this layout each
    cell's slice of ``FleetParams``/``FleetState`` is one contiguous
    block — ``params.flops_per_s[c*n:(c+1)*n]`` etc. — so per-cell state
    is directly reshapeable to ``(C, n, ...)`` and vmappable, which is
    what ``core.mesh_router`` shards over a device mesh."""

    num_cells: int   # C edge cells
    per_cell: int    # n servers in every edge cell block
    num_cloud: int   # trailing CLOUD_CELL servers (shared, fleet-wide)

    @property
    def num_edge(self) -> int:
        return self.num_cells * self.per_cell

    @property
    def num_servers(self) -> int:
        return self.num_edge + self.num_cloud


def cell_major_order(cell) -> np.ndarray:
    """Server permutation into cell-major order: edge cells ascending
    (each keeping its internal order, so per-cell LRU tie-breaks are
    preserved), all ``CLOUD_CELL`` servers last. ``order[i]`` is the OLD
    index landing at new position ``i`` (numpy argsort convention)."""
    cell = np.asarray(cell)
    key = np.where(cell == CLOUD_CELL, np.iinfo(np.int64).max,
                   cell.astype(np.int64))
    return np.argsort(key, kind="stable")


def cell_layout(params: FleetParams) -> CellLayout:
    """Validate that ``params`` is cell-major and return its block shape.

    Requirements: edge cell ids are exactly ``0..C-1``, every cell owns
    the same number of servers in one contiguous ascending block, and
    all ``CLOUD_CELL`` servers trail the edge blocks. Raises
    ``ValueError`` otherwise — ``cell_major_order`` produces the fixing
    permutation (see ``permute_fleet``); unequal cell sizes cannot be
    blocked and need the fleet padded to a common size. An untopologied
    fleet (``params.cell is None``) is one cell with no cloud."""
    if params.cell is None:
        return CellLayout(num_cells=1,
                          per_cell=int(params.flops_per_s.shape[0]),
                          num_cloud=0)
    cell = np.asarray(params.cell)
    n_total = int(cell.shape[0])
    is_cloud = cell == CLOUD_CELL
    num_cloud = int(is_cloud.sum())
    if num_cloud and not is_cloud[n_total - num_cloud:].all():
        raise ValueError(
            "fleet is not cell-major: CLOUD_CELL servers must trail the "
            "edge blocks (apply cell_major_order/permute_fleet)"
        )
    edge = cell[: n_total - num_cloud]
    if edge.size == 0:
        raise ValueError("fleet has no edge servers")
    c = int(edge.max()) + 1
    counts = np.bincount(edge, minlength=c) if edge.min() >= 0 else None
    if counts is None or (counts == 0).any():
        raise ValueError(
            f"edge cell ids must be exactly 0..C-1, got "
            f"{sorted(set(edge.tolist()))}"
        )
    if not (counts == counts[0]).all():
        raise ValueError(
            "cells must be equal-sized for the blocked layout, got "
            f"per-cell counts {counts.tolist()}; pad the fleet"
        )
    per = int(counts[0])
    if not np.array_equal(edge, np.repeat(np.arange(c), per)):
        raise ValueError(
            "edge servers are not grouped into contiguous ascending cell "
            "blocks (apply cell_major_order/permute_fleet)"
        )
    return CellLayout(num_cells=c, per_cell=per, num_cloud=num_cloud)


def permute_fleet(params: FleetParams, state: FleetState, order):
    """Apply a server permutation to every per-server axis of
    ``(params, state)`` — e.g. ``cell_major_order(params.cell)`` to bring
    an arbitrary fleet into the blocked layout. Choices reported against
    the permuted fleet map back through ``order[choice]``. Per-CELL
    arrays (``spill``) ride through unchanged: cell ids are preserved."""
    order = jnp.asarray(np.asarray(order), jnp.int32)
    new_params = params._replace(
        flops_per_s=params.flops_per_s[order],
        uplink_bps=params.uplink_bps[order],
        backhaul_bps=params.backhaul_bps[order],
        cache_slots=params.cache_slots[order],
        cell=None if params.cell is None else params.cell[order],
        drain_rate=(None if params.drain_rate is None
                    else params.drain_rate[order]),
    )
    new_state = state._replace(
        resident=state.resident[order],
        last_use=state.last_use[order],
        queue_tokens=state.queue_tokens[order],
    )
    return new_params, new_state


def local_block_params(params: FleetParams, layout: CellLayout,
                       block: int = 0) -> FleetParams:
    """One cell block's LOCAL fleet view: its ``per_cell`` edge servers
    relabeled to cell 0, plus the shared cloud columns (cell stays
    ``CLOUD_CELL``). Every block shares this geometry, so a policy built
    against the block-0 template (``core.policies.
    actor_policy_for_cell_blocks``) serves all cells under
    ``core.mesh_router.route_batch_sharded``."""
    c, n, nc = layout.num_cells, layout.per_cell, layout.num_cloud
    lo, hi = block * n, (block + 1) * n
    edge_total = c * n

    def take(x):
        blk = x[lo:hi]
        return jnp.concatenate([blk, x[edge_total:edge_total + nc]]) if nc \
            else blk

    local_cell = jnp.asarray(np.concatenate(
        [np.zeros(n, np.int32), np.full(nc, CLOUD_CELL, np.int32)]
    ))
    return params._replace(
        flops_per_s=take(params.flops_per_s),
        uplink_bps=take(params.uplink_bps),
        backhaul_bps=take(params.backhaul_bps),
        cache_slots=take(params.cache_slots),
        cell=local_cell,
        drain_rate=(None if params.drain_rate is None
                    else take(params.drain_rate)),
        # the local view relabels cells to {0, CLOUD_CELL}: the global
        # adjacency is meaningless here (spill fleets take the
        # full-replication sharded path instead)
        spill=None,
    )


# ---------------------------------------------------------------------------
# vectorised scoring
# ---------------------------------------------------------------------------
def _static_costs(params: FleetParams, reqs: RequestBatch, eta=None):
    """State-independent pieces of the eq. 11 score, one shot per batch:
    eq. 5 transmission (B, N), eq. 7 switch price (B, N) before the
    residency gate, and per-request decode FLOPs/token (B,). ``eta``
    scales the transmitted prompt — ``(x * eta) / r`` is the IEEE
    grouping of eq. 5's ``x eta / r``, so ``None`` is bitwise eta=1."""
    prompt = reqs.prompt_bits if eta is None else reqs.prompt_bits * eta
    t_trans = costs.trans_latency(
        prompt[:, None], 1.0, params.uplink_bps[None, :]
    )
    switch_price = costs.switch_latency(
        params.size_bits[reqs.model][:, None], params.backhaul_bps[None, :]
    )
    flops_tok = params.decode_flops_per_token[reqs.model]
    return t_trans, switch_price, flops_tok


def _spill_adjacency(params: FleetParams, reqs: RequestBatch):
    """(B, N) bool: server reachable through the neighbour-cell spill
    adjacency (``None`` when the fleet carries no ``spill``). May overlap
    the home cell when the adjacency has a true diagonal — callers that
    price the surcharge must exclude home pairs. Out-of-range cells on
    either side (orphan requests, ``CLOUD_CELL`` servers) never spill."""
    if params.spill is None or params.cell is None or reqs.cell is None:
        return None
    nc = params.spill.shape[0]
    rc, sc = reqs.cell, params.cell
    rok = (rc >= 0) & (rc < nc)
    sok = (sc >= 0) & (sc < nc)
    adj = params.spill[jnp.clip(rc, 0, nc - 1)][:, jnp.clip(sc, 0, nc - 1)]
    return adj & rok[:, None] & sok[None, :]


def cell_mask(params: FleetParams, reqs: RequestBatch):
    """(B, N) block-diagonal visibility mask, or ``None`` when untopologied.

    True where the server is in the request's cell OR in the reserved
    ``CLOUD_CELL`` (the fleet-wide cloud-fallback column) OR reachable
    through the ``FleetParams.spill`` neighbour-cell adjacency. ``None``
    — returned when either side carries no cell ids — means "everything
    visible" and lets callers compile the mask away statically."""
    if params.cell is None or reqs.cell is None:
        return None
    visible = (params.cell[None, :] == reqs.cell[:, None]) | (
        params.cell[None, :] == CLOUD_CELL
    )
    adj = _spill_adjacency(params, reqs)
    return visible if adj is None else visible | adj


def score_matrix(params: FleetParams, state: FleetState, reqs: RequestBatch,
                 *, backend: Optional[str] = None):
    """Full (B, N) eq. 11 cost matrix against the CURRENT fleet state.

    One shot over all request x server pairs: eq. 5 transmission +
    eq. 7 switch (gated on residency) + eq. 9 compute against the
    present queue backlog. Out-of-cell pairs score ``+inf`` when the
    batch carries cell ids (block-diagonal mask + cloud column).

    ``backend`` picks the contraction: ``"xla"`` (the reference path,
    arithmetic in ``costs.edge_score_matrix``) or ``"pallas"`` /
    ``"pallas-interpret"`` (the fused ``kernels/route_score.py`` tile
    kernel). ``None`` reads ``$REPRO_ROUTER_BACKEND``. Policy studies,
    admission control, and ``route_batch``'s chunked phase-1 all target
    exactly this contraction. ``reqs.eta``/``reqs.beta`` ride through to
    the backend (eq. 16 partial offload / download refusal); the matrix
    stays EDGE-SIDE — the eq. 3 local share never enters the scores
    (``max`` with it is monotone, so edge argmins are eq. 13 argmins)."""
    backend = resolve_backend(backend)
    flops_tok = params.decode_flops_per_token[reqs.model]
    has_cells = params.cell is not None and reqs.cell is not None
    return ops.route_score(
        reqs.prompt_bits, params.size_bits[reqs.model], flops_tok,
        reqs.gen_tokens * flops_tok,
        params.uplink_bps, params.backhaul_bps, params.flops_per_s,
        queue_tokens=state.queue_tokens, resident=state.resident,
        model=reqs.model,
        req_cell=reqs.cell if has_cells else None,
        srv_cell=params.cell if has_cells else None,
        spill=params.spill if has_cells else None,
        eta=reqs.eta, beta=reqs.beta,
        cloud_cell=CLOUD_CELL, backend=backend,
    )


def rejection_cause(params: FleetParams, reqs: RequestBatch, outage,
                    choice) -> jnp.ndarray:
    """(B,) int32 cause codes for a routed batch, derived POST-HOC.

    Whether a rejection was *structural* never depends on the fleet
    state — only on visibility (cells + spill + cloud) and the outage
    mask — so the channel is a pure function of the routed choices:

    * ``CAUSE_COMPLETED`` (0)  — ``choice >= 0``;
    * ``CAUSE_ADMISSION`` (2)  — some visible server was up, so a finite
      eq. 11 score existed: the request was refused because its best
      score exceeded ``deadline_s`` (SLO admission control);
    * ``CAUSE_OUTAGE``   (3)  — servers were visible but every one of
      them was outaged;
    * ``CAUSE_INFEASIBLE`` (1) — no server was visible at all (empty
      cell with no cloud column).

    Every router path shares this helper, so the per-cause rates in
    ``stats``/``window_stats`` agree bitwise across scan / chunked /
    speculative / sharded."""
    b = reqs.model.shape[0]
    completed = choice >= 0
    vis = cell_mask(params, reqs)
    if vis is None:
        any_vis = jnp.ones((b,), bool)
        any_up = (any_vis if outage is None
                  else jnp.broadcast_to(jnp.any(~outage), (b,)))
    else:
        any_vis = vis.any(axis=1)
        any_up = (any_vis if outage is None
                  else (vis & ~outage[None, :]).any(axis=1))
    rejected = jnp.where(
        any_up, CAUSE_ADMISSION,
        jnp.where(any_vis, CAUSE_OUTAGE, CAUSE_INFEASIBLE),
    )
    return jnp.where(completed, CAUSE_COMPLETED, rejected).astype(jnp.int32)


# ---------------------------------------------------------------------------
# policies: (latencies (N,), obs (3N,), queue (N,)[, ctx]) -> server index
# (full contract in the module docstring)
# ---------------------------------------------------------------------------
class PolicyCtx(NamedTuple):
    """Per-request context handed to policies with ``needs_ctx = True``.

    Everything is as of DECISION time: after the wall-clock queue decay,
    before the commit. ``queue`` is the raw (unmasked) depth vector —
    ``lats`` already carries the cell mask as ``+inf``."""

    params: FleetParams
    model: jnp.ndarray        # () int32 tagged catalogue index
    prompt_bits: jnp.ndarray  # ()
    gen_tokens: jnp.ndarray   # ()
    flops_tok: jnp.ndarray    # () decode FLOPs/token of the tagged model
    resident: jnp.ndarray     # (N,) bool residency of the tagged model
    queue: jnp.ndarray        # (N,) raw queue depths
    cell: Optional[jnp.ndarray] = None  # () int32, None when untopologied


class ChunkPolicyCtx(NamedTuple):
    """Chunk-level context for policies with a ``chunk_precompute`` hook.

    The request columns cover one whole chunk; ``resident`` is the fleet
    residency AT CHUNK ENTRY — decisions precomputed against it are
    provisional, and ``chunk_apply`` must detect drift per request."""

    params: FleetParams
    model: jnp.ndarray        # (c,) int32 tagged catalogue indices
    prompt_bits: jnp.ndarray  # (c,)
    gen_tokens: jnp.ndarray   # (c,)
    flops_tok: jnp.ndarray    # (c,)
    resident: jnp.ndarray     # (N, K) bool chunk-entry residency
    cell: Optional[jnp.ndarray] = None  # (c,) int32, None when untopologied


def _greedy_policy(lats, obs, queue):
    return jnp.argmin(lats)


def _load_policy(lats, obs, queue):
    return jnp.argmin(queue)


def _drain_policy(lats, obs, queue, ctx):
    """Drain-aware greedy: discount the queue backlog by the server's
    continuous ``drain_rate`` before the eq. 9 pricing.

    Eq. 9 prices the backlog as pure compute, ``q * ftok / f``. With a
    continuous drain of ``r`` tokens/sec the backlog is also being
    consumed while the request waits, so the self-consistent wait
    ``t_q = (q - r * t_q) * ftok / f`` solves to

        t_q = q * ftok / (f + r * ftok)

    i.e. the backlog is discounted by ``f / (f + r * ftok)``. The policy
    swaps that term into the eq. 11 score and argmins; the REPORTED
    latency stays the undiscounted eq. 11 value at the chosen server, so
    outcomes remain comparable across policies. ``drain_rate == 0`` (or
    absent) makes the score identical to greedy's."""
    rate = ctx.params.drain_rate
    if rate is None:
        return jnp.argmin(lats)
    f = ctx.params.flops_per_s
    backlog = ctx.queue * ctx.flops_tok
    return jnp.argmin(lats - backlog / f + backlog / (f + rate * ctx.flops_tok))


_greedy_policy.needs_obs = False
_load_policy.needs_obs = False
_drain_policy.needs_obs = False
_drain_policy.needs_ctx = True

#: Builtin argmin policies whose score is +inf exactly where the cell
#: mask is: they can only land out of cell when the whole row is
#: infeasible (-> rejected either way), so the chunked path skips the
#: out-of-cell clamp for them.
_ARGMIN_POLICIES = (_greedy_policy, _load_policy, _drain_policy)


def _make_actor_policy(actor: Callable[[Any, Any], Any]):
    def policy(lats, obs, queue):
        return jnp.asarray(actor(obs, lats), jnp.int32)

    policy.needs_obs = True
    return policy


def _resolve_policy(policy, actor):
    if callable(policy):
        return policy
    if policy == "greedy":
        return _greedy_policy
    if policy == "load":
        return _load_policy
    if policy == "drain":
        return _drain_policy
    if policy == "actor":
        if actor is None:
            raise ValueError("policy='actor' requires an actor callable")
        return _make_actor_policy(actor)
    raise ValueError(f"unknown policy {policy!r}")


# ---------------------------------------------------------------------------
# batched routing with sequential-commit semantics
# ---------------------------------------------------------------------------
def _commit(params, resident, last_use, queue, clock, model, gen_b, choice,
            lats, ok):
    """LRU residency + queue commit for one routed request, mirroring the
    scalar oracle. ``ok=None`` commits unconditionally (the single-cell
    un-padded fast path); a boolean ``ok`` gates every mutation — False
    leaves the fleet untouched and reports a rejection (choice -1)."""
    row = resident[choice]
    was_resident = row[model]
    full = row.sum() >= params.cache_slots[choice]
    evict_idx = jnp.argmin(
        jnp.where(row, last_use[choice], jnp.iinfo(jnp.int32).max)
    )
    if ok is None:
        evict = ~was_resident & full
        row = row.at[evict_idx].set(row[evict_idx] & ~evict)
        row = row.at[model].set(True)
        resident = resident.at[choice].set(row)
        last_use = last_use.at[choice, model].set(clock)
        queue = queue.at[choice].add(gen_b)
        out = (choice, lats[choice], was_resident)
    else:
        evict = ~was_resident & full & ok
        row = row.at[evict_idx].set(row[evict_idx] & ~evict)
        row = row.at[model].set(row[model] | ok)
        resident = resident.at[choice].set(row)
        last_use = last_use.at[choice, model].set(
            jnp.where(ok, clock, last_use[choice, model])
        )
        queue = queue.at[choice].add(jnp.where(ok, gen_b, 0.0))
        out = (jnp.where(ok, choice, -1), lats[choice], was_resident & ok)
    return resident, last_use, queue, out


def route_batch(
    params: FleetParams,
    state: FleetState,
    reqs: RequestBatch,
    drain_tokens=None,
    *,
    policy="greedy",
    actor=None,
    chunk: Optional[int] = None,
    unroll: int = 8,
    backend: Optional[str] = None,
    speculative: bool = True,
    outage=None,
):
    """Route a whole request batch in one jitted call; returns
    ``(state, outcome)``.

    Requests commit in arrival order (queue growth, LRU insert/evict)
    exactly like B sequential ``ModelAwareRouter.route`` calls, each
    followed by ``drain(drain_tokens)`` (scalar or (B,); None — the
    default — skips the drain update entirely in the compiled scan).

    Cell/drain knobs (both compiled out of the scan when absent):
      * ``reqs.cell`` + ``params.cell`` — block-diagonal visibility:
        each request scores ``+inf`` on out-of-cell servers, with
        ``CLOUD_CELL`` servers visible fleet-wide, so one call routes a
        whole multi-cell fleet.
      * ``reqs.arrival_s`` + ``params.drain_rate`` — time-based drain:
        before a request is scored, every queue decays by
        ``drain_rate * dt`` where ``dt`` is the wall-clock gap since the
        carry clock ``state.time_s`` last advanced.

    Robustness knobs (likewise compiled out when absent; see
    ``docs/robustness.md``):
      * ``reqs.deadline_s`` — SLO admission control: a request whose
        BEST eq. 11 score exceeds its deadline is rejected without
        committing (``+inf`` deadlines have no SLO).
      * ``params.spill`` — neighbour-cell spill: adjacent cells become
        visible at a backhaul surcharge, so overload spills to
        neighbours before the cloud column.
      * ``outage`` — (N,) bool fault mask: an outaged server's column
        scores ``+inf`` and its queue freezes (no drain) for this call.

    Eq. 16 action knobs (likewise compiled out when absent — ``None``
    stays bitwise today's path):
      * ``reqs.eta`` — partial offload: the edge share (eq. 5
        transmission, eq. 9 work, the committed queue tokens) scales by
        ``eta``; the device's retained ``1 - eta`` share is priced by
        ``reqs.local_flops_per_s`` (eq. 3) and enters the REPORTED
        latency (eq. 13's max) and the SLO check, never the argmin.
      * ``reqs.beta`` — download refusal: ``False`` rows price every
        non-resident server at ``+inf`` (the eq. 7 fetch is refused),
        so a refused request either lands on a resident server or is
        rejected (CAUSE_ADMISSION) — a committed refusal is always a
        residency hit and never mutates residency.

    ``outcome.cause`` labels every rejection (``rejection_cause``), so
    ``stats``/``window_stats`` can report honest per-cause rates.

    Performance knobs (all static — each combination compiles once):
      * ``chunk`` — two-phase commit: score ``chunk`` requests per fused
        kernel call, then run the slimmed correction scan (see module
        docstring). ``None`` keeps the one-scan path whose latencies are
        bit-exact against the oracle; integer decisions and fleet state
        are identical either way. Batches that don't divide evenly are
        padded with inert requests that never touch the fleet.
      * ``unroll`` — lax.scan unroll factor for the sequential region.
      * ``backend`` — scoring backend for the chunked phase-1 / the
        fused kernel (``"xla"`` | ``"pallas"`` | ``"pallas-interpret"``;
        ``None`` reads ``$REPRO_ROUTER_BACKEND``).
      * ``speculative`` — on the chunked greedy path, commit each
        chunk's provisional decisions speculatively and replay only the
        suffix after the first residency-mutating commit (see module
        docstring). Decisions and fleet state are identical either way;
        ``False`` forces the plain correction scan (the A/B baseline).
    """
    backend = resolve_backend(backend)  # env read stays outside the jit cache
    return _route_batch(params, state, reqs, drain_tokens, outage,
                        policy=policy, actor=actor, chunk=chunk,
                        unroll=unroll, backend=backend,
                        speculative=speculative)


@functools.partial(
    jax.jit, static_argnames=("policy", "actor", "chunk", "unroll", "backend",
                              "speculative")
)
def _route_batch(params, state, reqs, drain_tokens, outage, *, policy, actor,
                 chunk, unroll, backend, speculative=True):
    policy_fn = _resolve_policy(policy, actor)
    return _route_core(params, state, reqs, drain_tokens, policy_fn,
                       chunk=chunk, unroll=unroll, backend=backend,
                       speculative=speculative, outage=outage)


def _route_core(params, state, reqs, drain_tokens, policy_fn, *, chunk,
                unroll, backend, speculative=True, outage=None):
    """The traceable body of :func:`route_batch` with the policy already
    resolved to a callable — ``core.mesh_router`` vmaps exactly this over
    cell blocks, so it must stay jit-free and policy-static."""
    dtype = jnp.result_type(reqs.prompt_bits, params.uplink_bps)

    gen_tokens = reqs.gen_tokens.astype(dtype)                  # (B,)
    drain = (
        None
        if drain_tokens is None
        else jnp.broadcast_to(jnp.asarray(drain_tokens, dtype),
                              reqs.model.shape)
    )
    has_cells = params.cell is not None and reqs.cell is not None
    has_time = params.drain_rate is not None and reqs.arrival_s is not None
    if outage is not None:
        outage = jnp.asarray(outage, bool)
    drain_rate = params.drain_rate.astype(dtype) if has_time else None
    if drain_rate is not None and outage is not None:
        # frozen queue: an outaged server stops draining for this call
        drain_rate = jnp.where(outage, 0.0, drain_rate)
    arrivals = reqs.arrival_s.astype(dtype) if has_time else None
    deadline = (reqs.deadline_s.astype(dtype)
                if reqs.deadline_s is not None else None)
    # eq. 16 knobs (compiled out when absent): eta scales the offloaded
    # share, beta gates the eq. 7 download, local prices the eq. 3 side
    eta = reqs.eta.astype(dtype) if reqs.eta is not None else None
    beta = (jnp.asarray(reqs.beta).astype(bool)
            if reqs.beta is not None else None)
    local = (reqs.local_flops_per_s.astype(dtype)
             if eta is not None and reqs.local_flops_per_s is not None
             else None)
    time0 = state.time_s if state.time_s is not None else 0.0
    carry = (state.resident, state.last_use,
             state.queue_tokens.astype(dtype), state.clock,
             jnp.asarray(time0, dtype))

    if chunk is None:
        carry, outs = _scan_full(params, reqs, carry, policy_fn, dtype,
                                 gen_tokens, drain, drain_rate, arrivals,
                                 deadline, outage, has_cells, has_time,
                                 unroll, eta, beta, local)
    else:
        carry, outs = _scan_chunked(params, reqs, carry, policy_fn, dtype,
                                    gen_tokens, drain, drain_rate, arrivals,
                                    deadline, outage, has_cells, has_time,
                                    chunk, unroll, backend, speculative,
                                    eta, beta, local)
    resident, last_use, queue, clock, time_s = carry
    choice, latency, hit = outs
    new_state = FleetState(
        resident=resident, last_use=last_use, queue_tokens=queue, clock=clock,
        time_s=time_s,
    )
    return new_state, RouteOutcome(
        choice=choice, latency=latency, hit=hit,
        cause=rejection_cause(params, reqs, outage, choice),
    )


def _scan_full(params, reqs, carry, policy_fn, dtype, gen_tokens, drain,
               drain_rate, arrivals, deadline, outage, has_cells, has_time,
               unroll, eta=None, beta=None, local=None):
    """Single-scan path: full eq. 11 re-derivation per step (bit-exact
    latencies vs the scalar oracle — same term order, same rounding).

    Visibility (cells + spill), the spill surcharge and the outage mask
    are all state-independent, so they fold into the precomputed
    ``t_trans`` panel — masked pairs carry ``+inf`` and the scan body
    stays a pure add chain. The surcharge lands ON the eq. 5 term
    before the eq. 7/9 adds, matching the oracle's term order bitwise.

    Eq. 16 knobs: ``eta`` pre-scales the eq. 5/9 edge share (and the
    commit queues ``eta * gen``); ``beta=False`` rows poison the eq. 7
    switch price to ``+inf`` (a refused download can never win — and a
    committed refusal is always a residency hit by construction);
    ``local`` prices the device's retained ``1 - eta`` share (eq. 3),
    which enters only the reported eq. 13 latency and the SLO check —
    never the argmin (``max`` with a constant is monotone in the edge
    score, so the edge argmin is already an eq. 13 argmin)."""
    t_trans, switch_price, flops_tok = _static_costs(params, reqs, eta)
    prompt_eff = (reqs.prompt_bits if eta is None
                  else reqs.prompt_bits * eta)
    if has_cells and params.spill is not None:
        adj = _spill_adjacency(params, reqs)
        spilled = adj & (params.cell[None, :] != reqs.cell[:, None])
        t_trans = t_trans + jnp.where(
            spilled,
            prompt_eff[:, None] / params.backhaul_bps[None, :], 0.0,
        )
    vis = cell_mask(params, reqs)
    if vis is not None:
        t_trans = jnp.where(vis, t_trans, jnp.inf)
    if outage is not None:
        t_trans = jnp.where(outage[None, :], jnp.inf, t_trans)
    if beta is not None:
        switch_price = jnp.where(beta[:, None], switch_price, jnp.inf)
    has_mask = vis is not None or outage is not None or beta is not None
    work = gen_tokens * flops_tok                               # (B,)
    tloc = None
    if eta is not None:
        if local is not None:  # eq. 3 on the UNSCALED work; <= 0: no device
            tloc = jnp.where(local > 0, ((1.0 - eta) * work) / local, 0.0)
        work = work * eta
    gen_eff = None if eta is None else gen_tokens * eta
    needs_ctx = getattr(policy_fn, "needs_ctx", False)
    prompt = reqs.prompt_bits if needs_ctx else None
    # the builtin argmins return indices in [0, N) by construction and
    # can only land out of cell when the whole row is +inf (-> rejected
    # either way): skip the fallback clamp for them
    needs_clamp = policy_fn not in _ARGMIN_POLICIES

    def step(carry, xs):
        resident, last_use, queue, clock, time_s = carry
        (model, t_trans_b, switch_b, flops_tok_b, work_b, drain_b, gen_b,
         cell_b, arrival_b, prompt_b, dl_b, gen_eff_b, tloc_b) = xs

        if has_time:  # wall-clock queue decay since the last arrival
            dt = jnp.maximum(arrival_b - time_s, 0.0)
            queue = jnp.maximum(queue - drain_rate * dt, 0.0)
            time_s = jnp.maximum(time_s, arrival_b)
        clock = clock + 1

        resident_m = resident[:, model]                         # (N,)
        t_switch = jnp.where(resident_m, 0.0, switch_b)
        t_comp = (queue * flops_tok_b + work_b) / params.flops_per_s
        lats = t_trans_b + t_switch + t_comp                    # eq. 11
        queue_vis = queue
        if has_mask:  # masked servers can never win the argmin
            queue_vis = jnp.where(jnp.isfinite(t_trans_b), queue, jnp.inf)

        if getattr(policy_fn, "needs_obs", True):
            # scalar _observe layout: [resident, queue, flops] per server
            obs = jnp.stack(
                [resident_m.astype(dtype), queue, params.flops_per_s], axis=-1
            ).reshape(-1)                                       # (3N,)
        else:
            obs = None
        if needs_ctx:
            ctx = PolicyCtx(
                params=params, model=model, prompt_bits=prompt_b,
                gen_tokens=gen_b, flops_tok=flops_tok_b,
                resident=resident_m, queue=queue,
                cell=cell_b if has_cells else None,
            )
            choice = jnp.asarray(policy_fn(lats, obs, queue_vis, ctx),
                                 jnp.int32)
        else:
            choice = jnp.asarray(policy_fn(lats, obs, queue_vis), jnp.int32)
        if needs_clamp:
            # an actor may ignore the inf-masked inputs or return an
            # index outside [0, N) — which a JAX gather would silently
            # clamp to server N-1. Never commit an out-of-cell or
            # out-of-range choice: fall back to the masked greedy argmin.
            safe = jnp.clip(choice, 0, lats.shape[0] - 1)
            choice_ok = choice == safe
            if has_mask:
                # lats (not t_trans) finiteness: a beta-refused pick
                # falls back to the resident-only argmin, like the
                # oracle; pre-beta the two conditions are identical
                choice_ok &= jnp.isfinite(lats[safe])
            choice = jnp.where(choice_ok, safe,
                               jnp.argmin(lats).astype(jnp.int32))

        # a cell with no members and no cloud column (or fully outaged)
        # leaves every candidate at inf: reject without committing; the
        # SLO check compares the BEST score — policy-independent, so an
        # admission rejection never depends on which server was picked
        ok = jnp.isfinite(lats[choice]) if has_mask else None
        if dl_b is not None:
            best = jnp.min(lats)
            if tloc_b is not None:  # eq. 13: the device share bounds below
                best = jnp.maximum(tloc_b, best)
            admit = best <= dl_b
            ok = admit if ok is None else ok & admit
        resident, last_use, queue, out = _commit(
            params, resident, last_use, queue, clock, model,
            gen_b if gen_eff_b is None else gen_eff_b, choice,
            lats, ok,
        )
        if tloc_b is not None:  # reported latency is eq. 13's max
            out = (out[0], jnp.maximum(tloc_b, out[1]), out[2])
        if drain_b is not None:  # None is static: compiled out of the scan
            d = (drain_b if outage is None
                 else jnp.where(outage, 0.0, drain_b))
            queue = jnp.maximum(queue - d, 0.0)
        return (resident, last_use, queue, clock, time_s), out

    xs = (reqs.model, t_trans, switch_price, flops_tok, work, drain,
          gen_tokens, reqs.cell if has_cells else None, arrivals, prompt,
          deadline, gen_eff, tloc)
    return jax.lax.scan(step, carry, xs, unroll=unroll)


_LRU_FREE = jnp.iinfo(jnp.int32).max  # lru_key for a non-resident slot


def _static_argmin(col, k):
    """First-min argmin over the leading ``k`` scalars of ``col``,
    unrolled as a select tournament (k is tiny and static: the model
    catalogue). Ties break to the LOWEST index, exactly like
    ``jnp.argmin`` and the scalar oracle's list-order scan — the left
    operand wins every ``<=`` and lower indices always sit left."""
    vals = [col[i] for i in range(k)]
    idxs = [jnp.int32(i) for i in range(k)]
    while len(vals) > 1:
        nxt_v, nxt_i = [], []
        for i in range(0, len(vals) - 1, 2):
            left = vals[i] <= vals[i + 1]
            nxt_v.append(jnp.where(left, vals[i], vals[i + 1]))
            nxt_i.append(jnp.where(left, idxs[i], idxs[i + 1]))
        if len(vals) % 2:
            nxt_v.append(vals[-1])
            nxt_i.append(idxs[-1])
        vals, idxs = nxt_v, nxt_i
    return idxs[0]


def _scan_chunked(params, reqs, carry, policy_fn, dtype, gen_tokens, drain,
                  drain_rate, arrivals, deadline, outage, has_cells, has_time,
                  chunk, unroll, backend, speculative=True,
                  eta=None, beta=None, local=None):
    """Two-phase commit: fused chunk scoring + slimmed correction scan,
    with the speculative parallel commit on top for the greedy policy
    (``speculative=True``; see the module docstring for the argument).

    The serial region also runs on a denser state encoding than the
    public ``FleetState`` (converted at entry/exit):

      * ``lru_ext`` — residency, LRU clocks AND spare-slot counts
        collapsed into ONE transposed (K+1, N) int32 array: rows
        ``0..K-1`` hold ``where(resident, last_use, INT32_MAX)``, row
        ``K`` the free cache slots. Residency becomes a compare, the
        eq. 7 gate reads one CONTIGUOUS row per step (the model axis is
        major), and a single column slice at the chosen server yields
        the hit bit, the eviction candidates and the capacity check in
        one read. The LRU victim is a first-min select tournament down
        the column — non-residents sort last automatically, and ties
        still break by model index exactly like the scalar oracle's
        list order.
      * the commit is a dense one-hot ``where`` over (K+1, N) — no
        scatter in the loop body at all — and the three per-step
        outputs ride in ONE stacked (3,) vector so the scan performs a
        single output write per request.

    ``last_use`` entries of models that leave residency mid-batch come
    back as their pre-batch values (the single-scan path keeps the
    eviction-time clock); those entries are dead state — the oracle
    never reads a non-resident clock."""
    b = reqs.model.shape[0]
    n = params.flops_per_s.shape[0]
    c = max(1, min(int(chunk), b))
    n_chunks = -(-b // c)
    pad = n_chunks * c - b

    def pad1(x):
        return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1)) if pad else x

    model = pad1(reqs.model)
    prompt = pad1(reqs.prompt_bits.astype(dtype))
    gen = pad1(gen_tokens)
    flops_tok = params.decode_flops_per_token[model]
    size_bits = params.size_bits[model]
    work = gen * flops_tok
    # eq. 16 knobs: eta pre-scales the edge share (prompt, work, and the
    # committed gen — same IEEE grouping as the oracle), beta=False
    # poisons the eq. 7 size to +inf (refused downloads never win), and
    # `local` prices the device's eq. 3 share, entering only the
    # reported eq. 13 latency and the SLO check — never the argmin
    tloc = None
    if eta is not None:
        eta_p = pad1(eta)
        if local is not None:
            local_p = pad1(local)
            tloc = jnp.where(local_p > 0,
                             ((1.0 - eta_p) * work) / local_p, 0.0)
        prompt_eff = prompt * eta_p
        work = work * eta_p
        gen_commit = gen * eta_p
        praw, graw = prompt, gen  # policies still see the raw columns
    else:
        prompt_eff, gen_commit = prompt, gen
        praw = graw = None
    if beta is not None:
        # pad1 pads False -> +inf size on pad rows; `valid` rejects them
        size_bits = jnp.where(pad1(beta), size_bits, jnp.inf)
    cells = pad1(reqs.cell) if has_cells else None
    arrs = pad1(arrivals) if has_time else None
    drains = pad1(drain) if drain is not None else None
    # padded deadline lanes are 0.0 — harmless, `valid` already rejects
    dls = pad1(deadline) if deadline is not None else None
    # padded tail requests are inert: no commit, no clock/time advance
    valid = (jnp.arange(n_chunks * c) < b) if pad else None
    # visibility rides in `base` as +inf; the outage mask folds into the
    # same channel (and the beta-poisoned switch price reaches `lats`
    # directly), so every downstream finiteness check covers all three
    has_mask = has_cells or outage is not None or beta is not None
    needs_obs = getattr(policy_fn, "needs_obs", True)
    needs_ctx = getattr(policy_fn, "needs_ctx", False)
    # the builtin argmins can only land on an invisible server when the
    # whole row is +inf (-> rejected either way), so the out-of-cell
    # clamp is skipped for them; every other policy gets clamped,
    # matching the single-scan path decision for decision
    needs_clamp = policy_fn not in _ARGMIN_POLICIES
    has_hook = needs_ctx and hasattr(policy_fn, "chunk_precompute")
    # speculative parallel commit: greedy only — its provisional argmin
    # depends on state only through (queue, residency), which the cheap
    # scan + drift replay reproduce exactly; other policies read obs/ctx
    use_spec = speculative and policy_fn is _greedy_policy
    iota_n = jnp.arange(n, dtype=jnp.int32)
    num_k = params.size_bits.shape[0]
    iota_k = jnp.arange(num_k + 1, dtype=jnp.int32)  # +1: free-slot row

    resident0, last_use0, queue, clock, time_s = carry
    free = (params.cache_slots
            - resident0.sum(axis=1).astype(jnp.int32))       # (N,)
    lru = jnp.concatenate(
        [jnp.where(resident0, last_use0, _LRU_FREE).T, free[None, :]]
    )                                                        # (K+1, N)
    carry = (lru, queue, clock, time_s)

    def chunks(x):
        return (
            None if x is None else x.reshape((n_chunks, c) + x.shape[1:])
        )

    def dense_commit(lru, queue, clock, model_b, gen_b, choice, ok):
        """Dense one-hot LRU/queue commit at ``choice``, shared between
        the correction scan and the speculative replay body: ONE column
        slice yields hit bit, eviction candidates and capacity check."""
        lru_col = jax.lax.dynamic_slice(
            lru, (jnp.int32(0), choice), (num_k + 1, 1)
        )[:, 0]
        was_resident = lru_col[model_b] < _LRU_FREE
        evict_idx = _static_argmin(lru_col, num_k)
        full = lru_col[num_k] <= 0                              # free slots
        evict = ~was_resident & full
        touch_n = iota_n == choice                              # (N,)
        if ok is None:
            out_choice, hit = choice, was_resident
        else:
            evict &= ok
            touch_n &= ok
            out_choice, hit = jnp.where(ok, choice, -1), was_resident & ok
        taken = (~was_resident).astype(jnp.int32) - evict.astype(jnp.int32)
        pair_set = (iota_k == model_b)[:, None] & touch_n[None, :]
        pair_evict = ((iota_k == evict_idx) & evict)[:, None] & touch_n[None, :]
        pair_free = (iota_k == num_k)[:, None] & touch_n[None, :]
        lru = jnp.where(
            pair_set, clock,
            jnp.where(pair_evict, _LRU_FREE,
                      lru - jnp.where(pair_free, taken, 0)),
        )
        queue = queue + jnp.where(touch_n, gen_b, 0.0)
        return lru, queue, out_choice, hit

    def step(carry, xs):
        lru, queue, clock, time_s = carry
        model_b, scal_b, drain_b, arrival_b, valid_b, dl_b, base_b, \
            prompt_b, cell_b, gctx_b, tloc_b, aux_b = xs
        gen_b, size_b, ftok_b = scal_b[0], scal_b[1], scal_b[2]
        # scal_b[0] is the COMMITTED gen (eta-scaled); policies see raw
        gen_ctx = gen_b if gctx_b is None else gctx_b

        if has_time:  # wall-clock residue: queue decay since last arrival
            dt = jnp.maximum(arrival_b - time_s, 0.0)
            if valid_b is not None:
                dt = jnp.where(valid_b, dt, 0.0)
                time_s = jnp.where(valid_b,
                                   jnp.maximum(time_s, arrival_b), time_s)
            else:
                time_s = jnp.maximum(time_s, arrival_b)
            queue = jnp.maximum(queue - drain_rate * dt, 0.0)
        clock = clock + (1 if valid_b is None
                         else valid_b.astype(clock.dtype))

        # state-dependent residue only: residency-gated switch (eq. 7)
        # + queue-backlog drift (eq. 9) on top of the precomputed
        # switch-free base (phase 1). Both residue terms are scalar x
        # (N,)-constant expressions, so the whole chain fuses into one
        # elementwise kernel — no per-step (N,) input rows beyond base.
        rm_key = jax.lax.dynamic_slice(
            lru, (model_b, jnp.int32(0)), (1, n)
        )[0]
        resident_m = rm_key < _LRU_FREE                         # (N,)
        lats = (
            base_b
            + jnp.where(resident_m, 0.0, size_b / params.backhaul_bps)
        ) + (queue * ftok_b) / params.flops_per_s

        if needs_obs:
            obs = jnp.stack(
                [resident_m.astype(dtype), queue, params.flops_per_s], axis=-1
            ).reshape(-1)
        else:
            obs = None
        queue_vis = queue
        if has_mask:
            # visibility/outage is already folded into base as +inf; XLA
            # DCEs this for policies that never read the queue (greedy)
            queue_vis = jnp.where(jnp.isfinite(base_b), queue, jnp.inf)
        if needs_ctx:
            ctx = PolicyCtx(
                params=params, model=model_b, prompt_bits=prompt_b,
                gen_tokens=gen_ctx, flops_tok=ftok_b, resident=resident_m,
                queue=queue, cell=cell_b,
            )
            if aux_b is not None:
                # chunk-level hook: the per-chunk precompute already did
                # the batched work; the per-step call only resolves the
                # precomputed decision against the live state. `exact`
                # flags whether that resolution matches what the policy
                # would decide per request — chunk_step replays the
                # whole chunk through the per-request path otherwise.
                choice, exact_b = policy_fn.chunk_apply(aux_b, ctx)
                choice = jnp.asarray(choice, jnp.int32)
                if valid_b is not None:  # inert pad rows never replay
                    exact_b |= ~valid_b
            else:
                choice = jnp.asarray(policy_fn(lats, obs, queue_vis, ctx),
                                     jnp.int32)
        else:
            choice = jnp.asarray(policy_fn(lats, obs, queue_vis), jnp.int32)
        if needs_clamp:
            # an actor may ignore the inf-masked inputs or return an
            # index outside [0, N) — which a JAX gather would silently
            # clamp to server N-1. Never commit an out-of-cell or
            # out-of-range choice: fall back to the masked greedy argmin.
            safe = jnp.clip(choice, 0, n - 1)
            choice_ok = choice == safe
            if has_mask:
                # lats (not base) finiteness: covers the beta-poisoned
                # switch residue too; pre-beta identical to base's
                choice_ok &= jnp.isfinite(lats[safe])
            choice = jnp.where(choice_ok, safe,
                               jnp.argmin(lats).astype(jnp.int32))

        lat_b = lats[choice]
        if tloc_b is not None:  # reported latency is eq. 13's max
            lat_b = jnp.maximum(tloc_b, lat_b)
        ok = jnp.isfinite(lat_b) if has_mask else None
        if dl_b is not None:  # SLO admission: best score vs deadline
            best = jnp.min(lats)
            if tloc_b is not None:
                best = jnp.maximum(tloc_b, best)
            admit = best <= dl_b
            ok = admit if ok is None else ok & admit
        if valid_b is not None:
            ok = valid_b if ok is None else ok & valid_b

        # dense one-hot commit on the (K+1, N) lru encoding
        lru, queue, out_choice, hit = dense_commit(
            lru, queue, clock, model_b, gen_b, choice, ok
        )
        # one stacked output vector -> one scan write per request
        cols = [out_choice.astype(dtype), lat_b, hit.astype(dtype)]
        if needs_ctx and aux_b is not None:
            cols.append(exact_b.astype(dtype))
        out = jnp.stack(cols)
        if drain_b is not None:
            d = drain_b if valid_b is None else jnp.where(valid_b, drain_b,
                                                          0.0)
            if outage is not None:  # frozen queue on outaged servers
                d = jnp.where(outage, 0.0, d)
            queue = jnp.maximum(queue - d, 0.0)
        return (lru, queue, clock, time_s), out

    def chunk_step(carry, xs):
        model_c, scal_c, prompt_c, work_c, drain_c, cell_c, arr_c, \
            valid_c, dl_c, praw_c, graw_c, tloc_c = xs
        # phase 1 — ONE fused kernel call scores the whole chunk: the
        # switch-free base (eq. 5 + zero-backlog eq. 9) with the cell
        # mask (incl. spill surcharge) folded in as +inf. Everything
        # here is state-independent; the switch price stays OUT of the
        # base because re-subtracting it on residency would cancel
        # catastrophically (the download price dwarfs the served
        # latencies) — the scan re-gates it.
        base = ops.route_score(
            prompt_c, None, scal_c[:, 2], work_c,
            params.uplink_bps, params.backhaul_bps, params.flops_per_s,
            req_cell=cell_c,
            srv_cell=params.cell if has_cells else None,
            spill=params.spill if has_cells else None,
            cloud_cell=CLOUD_CELL, backend=backend,
        )                                                       # (c, N)
        if outage is not None:
            base = jnp.where(outage[None, :], jnp.inf, base)

        def inner_xs(aux):
            prompt_ctx = prompt_c if praw_c is None else praw_c
            return (model_c, scal_c, drain_c, arr_c, valid_c, dl_c, base,
                    prompt_ctx if needs_ctx else None,
                    cell_c if needs_ctx and has_cells else None,
                    graw_c if needs_ctx else None, tloc_c, aux)

        if not has_hook:
            return jax.lax.scan(step, carry, inner_xs(None),
                                unroll=min(unroll, c))
        # chunk-level policy hook: batch the expensive per-request work
        # (e.g. the actor MLP) over the whole chunk against the
        # CHUNK-ENTRY residency; the scan resolves each step against
        # the live state and flags any it could not resolve exactly.
        # The replay for those lives HERE, per chunk, not per step: an
        # expensive per-step cond branch taxes every iteration just by
        # existing (its captured operands defeat the scan-body fusion),
        # while a chunk that never drifts past the precomputed variants
        # pays only one predicate for the whole chunk.
        cctx = ChunkPolicyCtx(
            params=params,
            model=model_c,
            prompt_bits=prompt_c if praw_c is None else praw_c,
            gen_tokens=scal_c[:, 0] if graw_c is None else graw_c,
            flops_tok=scal_c[:, 2],
            resident=(carry[0][:num_k] < _LRU_FREE).T,
            cell=cell_c if has_cells else None,
        )
        aux = policy_fn.chunk_precompute(cctx)
        fast_carry, fast_outs = jax.lax.scan(
            step, carry, inner_xs(aux), unroll=min(unroll, c))

        def keep(_):
            return fast_carry, fast_outs[:, :3]

        def replay(_):  # rerun the chunk through the per-request path
            return jax.lax.scan(step, carry, inner_xs(None),
                                unroll=min(unroll, c))

        return jax.lax.cond(jnp.all(fast_outs[:, 3] != 0.0),
                            keep, replay, None)

    def spec_chunk_step(carry, xs):
        lru, queue, clock, time_s = carry
        model_c, scal_c, prompt_c, work_c, drain_c, cell_c, arr_c, \
            valid_c, dl_c, praw_c, graw_c, tloc_c = xs
        gen_c, size_c, ftok_c = scal_c[:, 0], scal_c[:, 1], scal_c[:, 2]
        idx_c = jnp.arange(c, dtype=jnp.int32)

        # phase 1 — the same switch-free base the correction scan uses...
        base = ops.route_score(
            prompt_c, None, ftok_c, work_c,
            params.uplink_bps, params.backhaul_bps, params.flops_per_s,
            req_cell=cell_c,
            srv_cell=params.cell if has_cells else None,
            spill=params.spill if has_cells else None,
            cloud_cell=CLOUD_CELL, backend=backend,
        )                                                    # (c, N)
        if outage is not None:
            base = jnp.where(outage[None, :], jnp.inf, base)
        # ... plus the eq. 7 switch gate priced against the CHUNK-ENTRY
        # residency, applied with the per-step expression verbatim: the
        # speculative scores stay bitwise equal to the correction
        # scan's on every step where residency has not yet drifted
        hitrow = (lru[:num_k] < _LRU_FREE)[model_c]          # (c, N)
        basez = base + jnp.where(
            hitrow, 0.0, size_c[:, None] / params.backhaul_bps[None, :]
        )

        def spec_step(carry, xs_b):
            queue, time_s = carry
            basez_b, ftok_b, gen_b, drain_b, arrival_b, valid_b, dl_b, \
                tloc_b = xs_b
            if has_time:
                dt = jnp.maximum(arrival_b - time_s, 0.0)
                if valid_b is not None:
                    dt = jnp.where(valid_b, dt, 0.0)
                    time_s = jnp.where(valid_b,
                                       jnp.maximum(time_s, arrival_b), time_s)
                else:
                    time_s = jnp.maximum(time_s, arrival_b)
                queue = jnp.maximum(queue - drain_rate * dt, 0.0)
            # the whole speculative recurrence: residency (and with it
            # the argmin's score ordering) is FROZEN at chunk entry, so
            # only the queue backlog rides the carry — score, argmin,
            # one masked add. The choice itself is NOT emitted: the
            # queue trajectory alone reproduces it post-scan, bitwise
            lats = basez_b + (queue * ftok_b) / params.flops_per_s
            choice = jnp.argmin(lats).astype(jnp.int32)
            touch_n = iota_n == choice
            if has_mask:
                touch_n &= jnp.isfinite(basez_b[choice])
            if dl_b is not None:
                # greedy: lats[choice] IS the best score — the SLO check
                best = lats[choice]
                if tloc_b is not None:  # eq. 13 device-share floor
                    best = jnp.maximum(tloc_b, best)
                touch_n &= best <= dl_b
            if valid_b is not None:
                touch_n &= valid_b
            queue = queue + jnp.where(touch_n, gen_b, 0.0)
            if drain_b is not None:
                d = drain_b if valid_b is None else jnp.where(valid_b,
                                                              drain_b, 0.0)
                if outage is not None:
                    d = jnp.where(outage, 0.0, d)
                queue = jnp.maximum(queue - d, 0.0)
            out = (choice, queue) + ((time_s,) if has_time else ())
            return (queue, time_s), out

        inner = (basez, ftok_c, gen_c, drain_c, arr_c, valid_c, dl_c, tloc_c)
        _, souts = jax.lax.scan(spec_step, (queue, time_s), inner,
                                unroll=min(unroll, c))
        choices = souts[0]
        q_ext = jnp.concatenate([queue[None], souts[1]])     # (c+1, N)
        # everything the cheap scan did NOT emit comes back exactly,
        # vectorised, from the stored queue trajectory: re-running the
        # body's own expressions on its own carried values is bitwise
        q_pre = q_ext[:c]
        if has_time:
            t_ext = jnp.concatenate([time_s[None], souts[2]])
            dt_v = jnp.maximum(arr_c - t_ext[:c], 0.0)
            if valid_c is not None:
                dt_v = jnp.where(valid_c, dt_v, 0.0)
            q_pre = jnp.maximum(
                q_pre - drain_rate[None, :] * dt_v[:, None], 0.0
            )
        lats_full = basez + (q_pre * ftok_c[:, None]) / \
            params.flops_per_s[None, :]
        col = choices[:, None]
        lat = jnp.take_along_axis(lats_full, col, axis=1)[:, 0]
        if tloc_c is not None:  # eq. 13: reported latency and SLO floor
            lat = jnp.maximum(tloc_c, lat)
        hits = jnp.take_along_axis(hitrow, col, axis=1)[:, 0]
        ok = jnp.isfinite(lat) if has_mask else jnp.ones((c,), bool)
        if dl_c is not None:  # re-derived `lat` is bitwise the scan's
            ok &= lat <= dl_c
        okv = ok if valid_c is None else ok & valid_c
        # first conflicting commit: a committed MISS mutates residency
        # (install + possible eviction), invalidating later frozen
        # scores; committed HITS only touch LRU clocks, which no score
        # reads — everything before the first miss is oracle-exact
        miss = okv & ~hits
        i0 = jnp.where(miss.any(), jnp.argmax(miss).astype(jnp.int32),
                       jnp.int32(c))
        # clock advances per VALID request, committed or not
        cum = (idx_c + 1 if valid_c is None
               else jnp.cumsum(valid_c.astype(jnp.int32)))
        clocks = clock + cum                                 # (c,)
        # parallel commit of the speculative prefix: ONE scatter-max
        # applies every prefix hit's LRU clock (clocks grow with the
        # stream index, so duplicate (model, server) slots resolve to
        # the LATEST write — exactly the serial order); prefix queue
        # adds already live in the trajectory
        in_prefix = okv & hits & (idx_c < i0)
        scat_col = jnp.where(in_prefix, choices, n)          # n: dump lane
        lru = jnp.pad(lru, ((0, 0), (0, 1)))
        lru = lru.at[model_c, scat_col].max(clocks)[:, :n]
        # rewind carried state to the first conflicting commit ...
        queue = jnp.take(q_ext, i0, axis=0)
        clock = clock + jnp.where(i0 > 0, cum[jnp.maximum(i0 - 1, 0)], 0)
        if has_time:
            time_s = jnp.take(t_ext, i0, axis=0)
        och = jnp.where(okv, choices, -1)
        ohit = hits & okv

        def replay_body(i, st):
            # ... and replay the conflicting suffix serially with the
            # full correction-scan body (live residency via the same
            # expressions — bit-identical to the non-speculative path)
            lru, queue, clk, ts, och, olat, ohit = st
            model_b, gen_b = model_c[i], gen_c[i]
            valid_b = None if valid_c is None else valid_c[i]
            if has_time:
                arrival_b = arr_c[i]
                dt = jnp.maximum(arrival_b - ts, 0.0)
                if valid_b is not None:
                    dt = jnp.where(valid_b, dt, 0.0)
                    ts = jnp.where(valid_b, jnp.maximum(ts, arrival_b), ts)
                else:
                    ts = jnp.maximum(ts, arrival_b)
                queue = jnp.maximum(queue - drain_rate * dt, 0.0)
            clk = clk + (1 if valid_b is None else valid_b.astype(clk.dtype))
            rm_key = jax.lax.dynamic_slice(
                lru, (model_b, jnp.int32(0)), (1, n)
            )[0]
            resident_m = rm_key < _LRU_FREE
            lats = (
                base[i]
                + jnp.where(resident_m, 0.0,
                            size_c[i] / params.backhaul_bps)
            ) + (queue * ftok_c[i]) / params.flops_per_s
            choice = jnp.argmin(lats).astype(jnp.int32)
            lat_b = lats[choice]
            if tloc_c is not None:  # eq. 13 max, matching the scan body
                lat_b = jnp.maximum(tloc_c[i], lat_b)
            ok_b = jnp.isfinite(lat_b) if has_mask else None
            if dl_c is not None:  # greedy: lats[choice] == min(lats)
                admit = lat_b <= dl_c[i]
                ok_b = admit if ok_b is None else ok_b & admit
            if valid_b is not None:
                ok_b = valid_b if ok_b is None else ok_b & valid_b
            lru, queue, out_choice, hit_b = dense_commit(
                lru, queue, clk, model_b, gen_b, choice, ok_b
            )
            if drain_c is not None:
                d = drain_c[i]
                if valid_b is not None:
                    d = jnp.where(valid_b, d, 0.0)
                if outage is not None:
                    d = jnp.where(outage, 0.0, d)
                queue = jnp.maximum(queue - d, 0.0)
            och = och.at[i].set(out_choice)
            olat = olat.at[i].set(lat_b)
            ohit = ohit.at[i].set(hit_b)
            return (lru, queue, clk, ts, och, olat, ohit)

        st = (lru, queue, clock, time_s, och, lat, ohit)
        lru, queue, clock, time_s, och, olat, ohit = jax.lax.fori_loop(
            i0, c, replay_body, st
        )
        return (lru, queue, clock, time_s), (och, olat, ohit)

    # (c, 3) strip of per-request scalars: one xs slice per step.
    # Column 0 is the COMMITTED gen (eta-scaled when the knob is set);
    # the raw columns ride separately for policy ctx only.
    scalars = jnp.stack([gen_commit, size_bits, flops_tok], axis=1)
    xs = tuple(map(chunks, (model, scalars, prompt_eff, work,
                            drains, cells, arrs, valid, dls,
                            praw, graw, tloc)))
    carry, outs = jax.lax.scan(spec_chunk_step if use_spec else chunk_step,
                               carry, xs)
    lru, queue, clock, time_s = carry
    lru = lru[:num_k]                                        # drop free row
    resident = (lru < _LRU_FREE).T
    # non-resident clocks are dead state; restore pre-batch values so a
    # model that was evicted mid-batch doesn't surface a bogus clock
    last_use = jnp.where(resident, lru.T, last_use0)
    carry = (resident, last_use, queue, clock, time_s)
    if use_spec:                                             # unpack
        choice = outs[0].reshape(n_chunks * c)[:b]
        latency = outs[1].reshape(n_chunks * c)[:b]
        hit = outs[2].reshape(n_chunks * c)[:b]
    else:
        outs = outs.reshape(n_chunks * c, 3)[:b]
        choice = outs[:, 0].astype(jnp.int32)
        latency = outs[:, 1]
        hit = outs[:, 2] != 0
    return carry, (choice, latency, hit)


def stats(outcome: RouteOutcome, *, cloud_index: Optional[int] = None) -> dict:
    """Fleet-level summary of one routed batch.

    Rejected requests (``choice == -1``, ``inf`` latency) would poison
    the latency mean, so they are masked out of ``mean_latency`` and
    reported separately as ``completion_rate`` — the fraction of
    requests that found a feasible server (the paper's third headline
    metric alongside latency and hit rate). ``residency_hit_rate`` is
    masked the same way: rejected requests are forced ``hit=False`` by
    the router, so counting them in the mean would deflate the hit rate
    exactly in the rejection-heavy scenarios where it matters — it is
    the hit fraction OVER COMPLETED requests (``nan`` when none
    complete). ``download_rate`` is its complement over the same
    denominator — the fraction of completed requests whose commit
    fetched the model over the backhaul (an eq. 7/8 download; under
    ``beta=False`` refusal it is structurally 0, since a committed
    refusal is always a residency hit), so ``residency_hit_rate +
    download_rate == 1`` whenever any request completes.
    ``cloud_index`` — the cloud column's server index
    (conventionally the last) — adds the ``cloud_fallback_rate``, so
    call sites stop re-deriving it from raw choices.

    When the outcome carries a ``cause`` channel, the per-cause
    rejection rates (``infeasible_rate`` / ``admission_rate`` /
    ``outage_rate``) are reported over ALL requests — the same
    denominator as ``completion_rate``, so the four always sum to 1.
    """
    ok = outcome.choice >= 0
    n_ok = jnp.maximum(ok.sum(), 1)
    mean_lat = jnp.where(
        ok.any(),
        jnp.where(ok, outcome.latency, 0.0).sum() / n_ok,
        jnp.inf,
    )
    hit_rate = jnp.where(
        ok.any(),
        (outcome.hit & ok).sum() / n_ok,
        jnp.nan,
    )
    dl_rate = jnp.where(
        ok.any(),
        (ok & ~outcome.hit).sum() / n_ok,
        jnp.nan,
    )
    out = {
        "mean_latency": float(mean_lat),
        "residency_hit_rate": float(hit_rate),
        "download_rate": float(dl_rate),
        "completion_rate": float(ok.mean()),
    }
    if cloud_index is not None:
        out["cloud_fallback_rate"] = float(
            (outcome.choice == cloud_index).mean()
        )
    if outcome.cause is not None:
        for name, code in (("infeasible_rate", CAUSE_INFEASIBLE),
                           ("admission_rate", CAUSE_ADMISSION),
                           ("outage_rate", CAUSE_OUTAGE)):
            out[name] = float((outcome.cause == code).mean())
    return out


def window_stats(outcome: RouteOutcome, window_id, num_windows: int, *,
                 cloud_index: Optional[int] = None,
                 completed_means: Optional[dict] = None) -> dict:
    """Per-window ``stats`` over one routed stream: the same rejection
    masking, applied ONCE for all windows, so time-series aggregation
    (``workloads.simulate``) doesn't re-mask per call site.

    ``window_id`` assigns each request to a window in ``[0,
    num_windows)`` — any segmentation works (request-count chunks, wall-
    clock buckets). Returns ``(num_windows,)`` numpy arrays; a window
    with no completed requests reports ``inf`` mean latency and ``nan``
    hit rate / completed means (there is nothing to average — ``0.0``
    would read as an impossibly perfect measurement), an empty window
    zero rates. ``residency_hit_rate`` is the hit fraction over the
    window's COMPLETED requests, matching :func:`stats`.
    ``completed_means`` adds extra columns: each ``name -> (B,)``
    per-request value is averaged over the window's COMPLETED requests
    (values at rejected requests must already be zero — e.g.
    ``workloads.simulate.request_energy_j``). A ``cause`` channel on the
    outcome adds per-window ``infeasible_rate`` / ``admission_rate`` /
    ``outage_rate`` over the SAME all-requests denominator as
    ``completion_rate`` (the four sum to 1 in every window)."""
    wid = np.asarray(window_id)
    choice = np.asarray(outcome.choice)
    ok = choice >= 0
    count = np.bincount(wid, minlength=num_windows).astype(float)
    n_ok = np.bincount(wid, weights=ok, minlength=num_windows)
    lat_sum = np.bincount(
        wid, weights=np.where(ok, np.asarray(outcome.latency), 0.0),
        minlength=num_windows,
    )
    hits = np.bincount(wid, weights=np.asarray(outcome.hit) & ok,
                       minlength=num_windows)
    denom = np.maximum(count, 1.0)
    denom_ok = np.maximum(n_ok, 1.0)
    out = {
        "requests": count.astype(np.int64),
        "mean_latency": np.where(n_ok > 0, lat_sum / denom_ok, np.inf),
        "completion_rate": n_ok / denom,
        "residency_hit_rate": np.where(n_ok > 0, hits / denom_ok, np.nan),
        # complement of the hit rate over completed requests: commits
        # that fetched the model over the backhaul (eq. 7/8 downloads)
        "download_rate": np.where(n_ok > 0, (n_ok - hits) / denom_ok,
                                  np.nan),
    }
    if cloud_index is not None:
        out["cloud_fallback_rate"] = np.bincount(
            wid, weights=(choice == cloud_index), minlength=num_windows
        ) / denom
    if outcome.cause is not None:
        cz = np.asarray(outcome.cause)
        for name, code in (("infeasible_rate", CAUSE_INFEASIBLE),
                           ("admission_rate", CAUSE_ADMISSION),
                           ("outage_rate", CAUSE_OUTAGE)):
            out[name] = np.bincount(
                wid, weights=(cz == code), minlength=num_windows
            ) / denom
    for name, vals in (completed_means or {}).items():
        out[name] = np.where(
            n_ok > 0,
            np.bincount(wid, weights=np.asarray(vals),
                        minlength=num_windows) / denom_ok,
            np.nan,
        )
    return out
