"""ModelAwareRouter — the scalar REFERENCE ORACLE for request routing.

A fleet of edge servers (device groups in a real deployment) each caches
``cache_slots`` generative models. Generation requests arrive tagged
with a model index; the router assigns each request to a server, pricing
exactly the paper's cost terms per candidate:

    transmission (eq. 5)  +  model switch if not resident (eq. 7)
    +  compute at the server's share of capacity (eq. 9, FIFO-fair)

Three policies share the scoring code:
  * ``policy="greedy"``  — myopically minimise the eq. 11 latency
    (the paper's Greedy gets this wrong by ignoring switches/contention);
  * ``policy="drain"``   — drain-aware greedy: the queue backlog is
    discounted by the server's continuous ``drain_rate`` before the
    eq. 9 pricing (``q*ftok/(f + r*ftok)`` instead of ``q*ftok/f``), so
    fast-draining servers keep winning under bursts. The reported
    latency stays the undiscounted eq. 11 value at the choice;
  * ``policy="actor"``   — a trained MADDPG-MATO actor drives the choice
    (requests act as agents over the same observation layout as the env).

The router maintains LRU residency exactly like the environment, so a
policy trained in `core.env` transfers unchanged.

Multi-cell fleets: every server belongs to a ``cell`` (an edge site /
base-station coverage area); a request tagged with a cell only sees the
servers of that cell plus any server in the reserved ``CLOUD_CELL`` —
the cloud-fallback column, visible fleet-wide and priced through the
backhaul (its effective uplink folds the extra hop; see
``launch.serve.make_cloud_server``). Out-of-cell candidates score
``+inf`` and are never chosen.

Time-based drain: servers complete queued work continuously at
``drain_rate`` tokens/sec. Requests carry an ``arrival_s`` wall-clock
stamp; before a request is scored, every queue decays by
``drain_rate * dt`` where ``dt`` is the time elapsed since the fleet
clock last advanced. ``drain_rate == 0`` (the default) reproduces the
original synchronous behaviour exactly. The explicit ``drain(tokens)``
call remains for per-request token drains.

This implementation routes ONE request per call through readable Python
dataclass mutation. It is deliberately kept that way: it is the ground
truth that ``core.batch_router`` — the jitted, fleet-scale batched path
used by ``launch/serve.py`` — must match request for request
(tests/test_batch_router.py and tests/test_multicell_router.py pin
choices, latencies, residency and LRU evictions against it). Serving
code should use ``core.batch_router``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.catalog import CatalogEntry

#: Reserved cell id for cloud-fallback servers: visible from every cell.
CLOUD_CELL = -1

#: Rejection-cause codes shared by every router path (the batched and
#: sharded paths re-export them; ``batch_router.rejection_cause`` is the
#: formal definition). ``completion_rate + infeasible + admission +
#: outage == 1`` over any batch.
CAUSE_COMPLETED = 0   # routed and committed
CAUSE_INFEASIBLE = 1  # no visible server at all (empty cell, no cloud)
CAUSE_ADMISSION = 2   # best eq. 11 score exceeded the request's SLO
CAUSE_OUTAGE = 3      # servers visible, but every one of them outaged


@dataclasses.dataclass
class EdgeServer:
    name: str
    flops_per_s: float
    cache_slots: int
    uplink_bps: float
    backhaul_bps: float
    resident: list[int] = dataclasses.field(default_factory=list)
    last_use: dict = dataclasses.field(default_factory=dict)
    queue_tokens: float = 0.0  # outstanding work, FIFO
    cell: int = 0              # edge site; CLOUD_CELL == visible fleet-wide
    drain_rate: float = 0.0    # tokens/sec completed continuously
    outaged: bool = False      # fault injection: +inf column, frozen queue


@dataclasses.dataclass
class Request:
    model: int
    prompt_bits: float
    gen_tokens: int
    cell: int = 0              # which cell the requesting device sits in
    arrival_s: float | None = None  # wall-clock arrival (None: no time drain)
    deadline_s: float | None = None  # SLO: reject if best score exceeds it
    #: eq. 16 offload ratio in [0, 1]: the edge side transmits/computes
    #: the ``eta`` fraction, the device keeps ``1 - eta`` (``None``
    #: prices like 1.0 — today's full-offload serving — bit-exactly)
    eta: float | None = None
    #: eq. 16 download decision: ``False`` refuses the eq. 7 model fetch
    #: on a residency miss (non-resident candidates price ``+inf``);
    #: ``None``/``True`` downloads as before
    beta: bool | None = None
    #: device compute speed for the eq. 3 local share; ``None`` prices
    #: the local side at zero (pure edge latency, as before)
    local_flops_per_s: float | None = None


class ModelAwareRouter:
    def __init__(self, servers: list[EdgeServer], catalog: list[CatalogEntry],
                 policy: str = "greedy", actor=None, spill=None):
        self.servers = servers
        self.catalog = {e.index: e for e in catalog}
        self.policy = policy
        self.actor = actor
        self.clock = 0
        self.time_s = 0.0  # wall clock for the time-based drain
        #: (C, C) bool neighbour-cell adjacency: ``spill[rc][sc]`` makes
        #: cell ``sc`` visible from cell ``rc`` at a backhaul surcharge.
        self.spill = None if spill is None else np.asarray(spill, bool)
        #: cause code of the LAST ``route`` call (CAUSE_*).
        self.last_cause = None

    # ------------------------------------------------------------------
    def _candidate_latency(self, srv: EdgeServer, req: Request) -> float:
        entry = self.catalog[req.model]
        prompt = req.prompt_bits
        work = req.gen_tokens * entry.decode_flops_per_token
        if req.eta is not None:
            # eq. 16 partial offload: the edge side only sees the eta
            # fraction of the prompt (eq. 5) and the work (eq. 9); the
            # (1 - eta) local remainder is priced in ``route`` (it is
            # per-request, not per-candidate)
            prompt = prompt * req.eta
            work = work * req.eta
        t_trans = prompt / srv.uplink_bps                           # eq. (5)
        if self._spilled(srv, req):
            # neighbour-cell spill surcharge: the prompt crosses the
            # inter-cell backhaul on top of the uplink
            t_trans = t_trans + prompt / srv.backhaul_bps
        if req.model in srv.resident:
            t_switch = 0.0
        elif req.beta is not None and not req.beta:
            # download refusal: a miss cannot be served here at all
            t_switch = float("inf")
        else:
            t_switch = entry.switch_latency(srv.backhaul_bps)       # eq. (7)
        backlog = srv.queue_tokens * entry.decode_flops_per_token
        t_comp = (backlog + work) / srv.flops_per_s                 # eq. (9)
        return t_trans + t_switch + t_comp                          # eq. (11)

    def _local_latency(self, req: Request) -> float:
        """Eq. 3 share the device keeps under partial offload; 0.0 when
        the eta knob (or the device speed) is absent."""
        if (req.eta is None or req.local_flops_per_s is None
                or req.local_flops_per_s <= 0):
            return 0.0
        work = req.gen_tokens * self.catalog[req.model].decode_flops_per_token
        return ((1.0 - req.eta) * work) / req.local_flops_per_s

    def _drain_score(self, srv: EdgeServer, req: Request, lat: float) -> float:
        """Drain-aware decision score: swap eq. 9's backlog term for the
        self-consistent drained wait ``q*ftok/(f + r*ftok)`` (the backlog
        is consumed by compute AND the continuous drain while the request
        waits). Mirrors ``batch_router._drain_policy`` term for term."""
        ftok = self.catalog[req.model].decode_flops_per_token
        backlog = srv.queue_tokens * ftok
        return (
            lat - backlog / srv.flops_per_s
            + backlog / (srv.flops_per_s + srv.drain_rate * ftok)
        )

    def _spilled(self, srv: EdgeServer, req: Request) -> bool:
        """True when ``srv`` is reachable only through the neighbour-cell
        spill adjacency (never for home or cloud servers)."""
        if self.spill is None or srv.cell == req.cell:
            return False
        c = len(self.spill)
        if not (0 <= req.cell < c and 0 <= srv.cell < c):
            return False  # orphan request / cloud server: no spill
        return bool(self.spill[req.cell][srv.cell])

    def _visible(self, srv: EdgeServer, req: Request) -> bool:
        """Cell visibility: in-cell servers, the fleet-wide cloud, plus
        any cell reachable through the ``spill`` adjacency."""
        return (srv.cell == req.cell or srv.cell == CLOUD_CELL
                or self._spilled(srv, req))

    def advance_time(self, t_s: float):
        """Drain every queue by ``drain_rate * dt`` up to wall clock
        ``t_s``. Outaged servers' queues are frozen."""
        dt = max(float(t_s) - self.time_s, 0.0)
        for s in self.servers:
            if not s.outaged:
                s.queue_tokens = max(0.0, s.queue_tokens - s.drain_rate * dt)
        self.time_s = max(self.time_s, float(t_s))

    def route(self, req: Request) -> tuple[int, float]:
        """Returns (server index, predicted latency) and commits state.

        A rejection (-1, inf) leaves the fleet untouched and records why
        in ``self.last_cause``: no visible server (CAUSE_INFEASIBLE),
        every visible server outaged (CAUSE_OUTAGE), or the best eq. 11
        score above the request's ``deadline_s`` (CAUSE_ADMISSION)."""
        if req.arrival_s is not None:
            self.advance_time(req.arrival_s)
        self.clock += 1
        lats = [
            self._candidate_latency(s, req)
            if self._visible(s, req) and not s.outaged
            else float("inf")
            for s in self.servers
        ]
        if self.policy == "actor" and self.actor is not None:
            choice = int(self.actor(self._observe(req), lats))
            if not np.isfinite(lats[choice]):
                # never commit a masked (out-of-cell / outaged) actor
                # choice — fall back to the masked greedy argmin
                # (mirrors the batched path's finiteness clamp)
                choice = int(np.argmin(lats))
        elif self.policy == "drain":
            scores = [
                self._drain_score(s, req, lat) if np.isfinite(lat)
                else float("inf")
                for s, lat in zip(self.servers, lats)
            ]
            choice = int(np.argmin(scores))
        else:
            choice = int(np.argmin(lats))
        t_local = self._local_latency(req)
        best = max(t_local, min(lats))  # eq. 13: device and edge overlap
        deadline = float("inf") if req.deadline_s is None \
            else float(req.deadline_s)
        if not np.isfinite(lats[choice]) or best > deadline:
            # reject without mutating any state; the SLO check compares
            # the BEST eq. 13 total, so it never depends on the policy's
            # pick. The cause is STRUCTURAL — visibility and outage
            # masks, not score finiteness — so a beta refusal that
            # leaves every up candidate at +inf still reads as an
            # admission problem, matching ``batch_router.rejection_cause``
            visible = [self._visible(s, req) for s in self.servers]
            if any(v and not s.outaged
                   for v, s in zip(visible, self.servers)):
                self.last_cause = CAUSE_ADMISSION
            elif any(visible):
                self.last_cause = CAUSE_OUTAGE
            else:
                self.last_cause = CAUSE_INFEASIBLE
            return -1, float("inf")
        self.last_cause = CAUSE_COMPLETED
        srv = self.servers[choice]
        # commit: LRU residency + queue. Under a beta refusal a committed
        # request is always a residency hit (misses priced +inf above),
        # so the install below is a no-op there by construction.
        if req.model not in srv.resident:
            if len(srv.resident) >= srv.cache_slots:
                evict = min(srv.resident, key=lambda m: srv.last_use.get(m, -1))
                srv.resident.remove(evict)
            srv.resident.append(req.model)
        srv.last_use[req.model] = self.clock
        gen = req.gen_tokens if req.eta is None else req.gen_tokens * req.eta
        srv.queue_tokens += gen  # the edge only queues the offloaded share
        return choice, max(t_local, lats[choice])

    def _observe(self, req: Request):
        obs = []
        for s in self.servers:
            obs.extend([
                float(req.model in s.resident),
                s.queue_tokens,
                s.flops_per_s,
            ])
        return np.asarray(obs, np.float32)

    def drain(self, tokens: float):
        """Advance time: every server completes ``tokens`` of queued
        work. Outaged servers' queues are frozen."""
        for s in self.servers:
            if not s.outaged:
                s.queue_tokens = max(0.0, s.queue_tokens - tokens)

    def stats(self, requests, latencies):
        hits = sum(
            1 for r, (c, _) in zip(requests, latencies)
            if r.model in self.servers[c].resident
        )
        return {
            "mean_latency": float(np.mean([l for _, l in latencies])),
            "residency_hit_rate": hits / max(len(requests), 1),
        }
