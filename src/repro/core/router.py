"""ModelAwareRouter — the scalar REFERENCE ORACLE for request routing.

A fleet of edge servers (device groups in a real deployment) each caches
``cache_slots`` generative models. Generation requests arrive tagged
with a model index; the router assigns each request to a server, pricing
exactly the paper's cost terms per candidate:

    transmission (eq. 5)  +  model switch if not resident (eq. 7)
    +  compute at the server's share of capacity (eq. 9, FIFO-fair)

Two policies share the scoring code:
  * ``policy="greedy"``  — myopically minimise the eq. 11 latency
    (the paper's Greedy gets this wrong by ignoring switches/contention);
  * ``policy="actor"``   — a trained MADDPG-MATO actor drives the choice
    (requests act as agents over the same observation layout as the env).

The router maintains LRU residency exactly like the environment, so a
policy trained in `core.env` transfers unchanged.

This implementation routes ONE request per call through readable Python
dataclass mutation. It is deliberately kept that way: it is the ground
truth that ``core.batch_router`` — the jitted, fleet-scale batched path
used by ``launch/serve.py`` — must match request for request
(tests/test_batch_router.py pins choices, latencies, residency and LRU
evictions against it). Serving code should use ``core.batch_router``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.catalog import CatalogEntry


@dataclasses.dataclass
class EdgeServer:
    name: str
    flops_per_s: float
    cache_slots: int
    uplink_bps: float
    backhaul_bps: float
    resident: list[int] = dataclasses.field(default_factory=list)
    last_use: dict = dataclasses.field(default_factory=dict)
    queue_tokens: float = 0.0  # outstanding work, FIFO


@dataclasses.dataclass
class Request:
    model: int
    prompt_bits: float
    gen_tokens: int


class ModelAwareRouter:
    def __init__(self, servers: list[EdgeServer], catalog: list[CatalogEntry],
                 policy: str = "greedy", actor=None):
        self.servers = servers
        self.catalog = {e.index: e for e in catalog}
        self.policy = policy
        self.actor = actor
        self.clock = 0

    # ------------------------------------------------------------------
    def _candidate_latency(self, srv: EdgeServer, req: Request) -> float:
        entry = self.catalog[req.model]
        t_trans = req.prompt_bits / srv.uplink_bps                  # eq. (5)
        t_switch = (
            0.0 if req.model in srv.resident
            else entry.switch_latency(srv.backhaul_bps)             # eq. (7)
        )
        backlog = srv.queue_tokens * entry.decode_flops_per_token
        work = req.gen_tokens * entry.decode_flops_per_token
        t_comp = (backlog + work) / srv.flops_per_s                 # eq. (9)
        return t_trans + t_switch + t_comp                          # eq. (11)

    def route(self, req: Request) -> tuple[int, float]:
        """Returns (server index, predicted latency) and commits state."""
        self.clock += 1
        lats = [self._candidate_latency(s, req) for s in self.servers]
        if self.policy == "actor" and self.actor is not None:
            choice = int(self.actor(self._observe(req), lats))
        else:
            choice = int(np.argmin(lats))
        srv = self.servers[choice]
        # commit: LRU residency + queue
        if req.model not in srv.resident:
            if len(srv.resident) >= srv.cache_slots:
                evict = min(srv.resident, key=lambda m: srv.last_use.get(m, -1))
                srv.resident.remove(evict)
            srv.resident.append(req.model)
        srv.last_use[req.model] = self.clock
        srv.queue_tokens += req.gen_tokens
        return choice, lats[choice]

    def _observe(self, req: Request):
        obs = []
        for s in self.servers:
            obs.extend([
                float(req.model in s.resident),
                s.queue_tokens,
                s.flops_per_s,
            ])
        return np.asarray(obs, np.float32)

    def drain(self, tokens: float):
        """Advance time: every server completes ``tokens`` of queued work."""
        for s in self.servers:
            s.queue_tokens = max(0.0, s.queue_tokens - tokens)

    def stats(self, requests, latencies):
        hits = sum(
            1 for r, (c, _) in zip(requests, latencies)
            if r.model in self.servers[c].resident
        )
        return {
            "mean_latency": float(np.mean([l for _, l in latencies])),
            "residency_hit_rate": hits / max(len(requests), 1),
        }
