"""Shared jitted evaluation harness for all five algorithms (paper §IV).

``rollout`` jits once per (policy_fn, EnvParams, AlgoConfig, episodes);
network parameters flow through as dynamic pytrees so evaluating a newly
trained agent never recompiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import baselines, env as env_lib, maddpg
from repro.core.types import EnvParams


# --- policy adaptors: (params, key, obs, p, cfg) -> Action --------------------
def policy_random(params, key, obs, p, cfg):
    del params, cfg
    return baselines.random_policy(key, obs, p)


def policy_greedy(params, key, obs, p, cfg):
    del params, cfg
    return baselines.greedy_policy(key, obs, p)


def policy_actor(params, key, obs, p, cfg):
    obs = maddpg._mask_obs(obs, p, cfg.model_aware)
    return maddpg.policy_action(params, obs, p, cfg, key, explore_scale=0.0)


@functools.partial(jax.jit, static_argnums=(1, 3, 4, 5))
def rollout(key, policy_fn, params, p: EnvParams, cfg, episodes: int):
    """Run deterministic episodes; return dict of scalar mean metrics."""

    def one_episode(key):
        k_reset, k_run = jax.random.split(key)
        state = env_lib.reset(k_reset, p)

        def step_fn(carry, _):
            state, key = carry
            key, k_act = jax.random.split(key)
            obs = env_lib.observe(state, p)
            act = policy_fn(params, k_act, obs, p, cfg)
            nxt, _, outcome, _ = env_lib.step(state, act, p)
            m = {
                "reward": outcome.reward.sum(),
                "latency": outcome.latency.mean(),
                "energy": outcome.energy.mean(),
                "completion": outcome.completed.mean(),
                "switch_latency": outcome.switch_latency.mean(),
            }
            return (nxt, key), m

        _, ms = jax.lax.scan(step_fn, (state, k_run), None, length=p.episode_len)
        return jax.tree.map(jnp.mean, ms)

    keys = jax.random.split(key, episodes)
    ms = jax.vmap(one_episode)(keys)
    return jax.tree.map(jnp.mean, ms)


def evaluate_policy(key, name: str, p: EnvParams, cfg=None, params=None, episodes=32):
    """Convenience dispatcher; returns python-float metric dict."""
    fn = {"random": policy_random, "greedy": policy_greedy, "actor": policy_actor}[name]
    if cfg is None:
        cfg = maddpg.AlgoConfig()
    out = rollout(key, fn, params, p, cfg, episodes)
    return {k: float(v) for k, v in out.items()}
