"""Non-learned baseline policies from paper §IV.A: Random and Greedy.

Both are pure functions ``(key, obs, p) -> Action`` so they plug into the
same jitted evaluation harness as the trained actors (``core.evaluate``).
They read only the per-agent observation (eq. 16) — compatibility bits,
ES positions and own position are all in there.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import env as env_lib
from repro.core.types import Action, EnvParams


def _obs_slices(p: EnvParams):
    k, n = p.num_models, p.num_ess
    i = 0
    sl = {}
    sl["type"] = (i, i + k); i += k
    sl["x"] = (i, i + 1); i += 1
    sl["rho"] = (i, i + 1); i += 1
    sl["f_es"] = (i, i + n); i += n
    sl["compat"] = (i, i + n); i += n
    sl["own_pos"] = (i, i + 2); i += 2
    sl["es_pos"] = (i, i + 2 * n); i += 2 * n
    sl["cc_pos"] = (i, i + 2); i += 2
    sl["f_ed"] = (i, i + 1); i += 1
    assert i == env_lib.obs_dim(p)
    return sl


def random_policy(key, obs, p: EnvParams) -> Action:
    """Uniform target/ratio/download — no model awareness (paper §IV.A)."""
    m = obs.shape[0]
    k1, k2, k3 = jax.random.split(key, 3)
    return Action(
        target=jax.random.randint(k1, (m,), 0, p.num_ess + 1),
        eta=jax.random.uniform(k2, (m,)),
        beta=(jax.random.uniform(k3, (m,)) > 0.5).astype(jnp.float32),
    )


def greedy_policy(key, obs, p: EnvParams) -> Action:
    """Nearest *compatible* ES with eta=1.0; local if none compatible."""
    del key
    sl = _obs_slices(p)
    compat = obs[:, sl["compat"][0] : sl["compat"][1]]  # (M, N)
    own = obs[:, sl["own_pos"][0] : sl["own_pos"][1]]  # (M, 2)
    es = obs[:, sl["es_pos"][0] : sl["es_pos"][1]].reshape(-1, p.num_ess, 2)
    dist = jnp.linalg.norm(es - own[:, None, :], axis=-1)  # (M, N)
    dist = jnp.where(compat > 0.5, dist, jnp.inf)
    best = jnp.argmin(dist, axis=-1)
    any_compat = compat.max(axis=-1) > 0.5
    target = jnp.where(any_compat, best + 1, 0).astype(jnp.int32)
    eta = jnp.where(any_compat, 1.0, 0.0)
    return Action(target=target, eta=eta, beta=jnp.zeros_like(eta))
