"""Jitted ring-buffer experience replay.

The buffer is a pytree of preallocated arrays with a functional ``add``
(donated in the training loop) and uniform sampling over the filled
prefix. Supports batched adds from vectorised environments.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Replay(NamedTuple):
    data: dict          # pytree; every leaf (capacity, ...)
    ptr: jnp.ndarray    # int32 next write slot
    size: jnp.ndarray   # int32 filled count


def init(capacity: int, example: dict) -> Replay:
    data = jax.tree.map(
        lambda x: jnp.zeros((capacity,) + jnp.shape(x), jnp.asarray(x).dtype),
        example,
    )
    return Replay(data=data, ptr=jnp.int32(0), size=jnp.int32(0))


def add_batch(buf: Replay, items: dict, n: int) -> Replay:
    """Insert ``n`` items (leaves shaped (n, ...)) with wraparound."""
    capacity = jax.tree.leaves(buf.data)[0].shape[0]
    idx = (buf.ptr + jnp.arange(n)) % capacity
    data = jax.tree.map(lambda d, x: d.at[idx].set(x), buf.data, items)
    return Replay(
        data=data,
        ptr=(buf.ptr + n) % capacity,
        size=jnp.minimum(buf.size + n, capacity),
    )


def sample(buf: Replay, key, batch: int) -> dict:
    idx = jax.random.randint(key, (batch,), 0, jnp.maximum(buf.size, 1))
    return jax.tree.map(lambda d: d[idx], buf.data)
