"""Minimal functional NN layer-kit (no flax in this environment).

Convention used across the framework: ``init(key, ...) -> params`` pytree,
``apply(params, x) -> y``. Per-agent networks are *stacked* parameter
pytrees (leading axis = agent) driven through ``jax.vmap`` — this realises
the paper's "each ED has its own actor/critic" with MXU-friendly batched
matmuls instead of M python-level modules.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def mlp_init(key, sizes: Sequence[int], final_scale: float = 1.0):
    params = []
    keys = jax.random.split(key, len(sizes) - 1)
    for i, k in enumerate(keys):
        fan_in, fan_out = sizes[i], sizes[i + 1]
        scale = jnp.sqrt(2.0 / fan_in)
        if i == len(keys) - 1:
            scale = scale * final_scale
        w = jax.random.normal(k, (fan_in, fan_out), jnp.float32) * scale
        b = jnp.zeros((fan_out,), jnp.float32)
        params.append({"w": w, "b": b})
    return params


def mlp_apply(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x


def stacked_init(key, num: int, sizes: Sequence[int], final_scale: float = 1.0):
    """num independent MLPs stacked on a leading axis."""
    keys = jax.random.split(key, num)
    return jax.vmap(lambda k: mlp_init(k, sizes, final_scale))(keys)


def stacked_apply(params, x):
    """params leading axis = agents; x: (num, ..., in) -> (num, ..., out)."""
    return jax.vmap(mlp_apply)(params, x)


def soft_update(target, online, tau: float):
    return jax.tree.map(lambda t, o: (1.0 - tau) * t + tau * o, target, online)
