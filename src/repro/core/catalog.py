"""Model catalogue: grounds the paper's abstract AIGC model set I={I_i, X_i}
(eq. 2) in the REAL assigned architectures.

The paper draws model sizes from U[90, 250] MB; here each catalogue entry
is one of the 10 assigned architectures with its actual parameter size
(bf16 serving bytes), per-token decode FLOPs (2 * N_active) and the
switch (download) latency over a given backhaul — so MADDPG-MATO
schedules over real model profiles, and the serving router (router.py)
prices model switches with the same numbers the roofline analysis uses.
"""
from __future__ import annotations

import dataclasses

from repro.configs import get_arch, list_archs


@dataclasses.dataclass(frozen=True)
class CatalogEntry:
    index: int
    name: str
    family: str
    param_count: int
    size_bits: float          # X_i — bf16 weights
    decode_flops_per_token: float

    def switch_latency(self, backhaul_bps: float) -> float:
        return self.size_bits / backhaul_bps  # paper eq. (7)

    def service_latency(self, tokens: int, flops_per_s: float) -> float:
        return tokens * self.decode_flops_per_token / flops_per_s


def build_catalog(archs=None) -> list[CatalogEntry]:
    entries = []
    for i, name in enumerate(archs or list_archs()):
        cfg = get_arch(name)
        n, na = cfg.param_count(), cfg.active_param_count()
        entries.append(
            CatalogEntry(
                index=i,
                name=name,
                family=cfg.family,
                param_count=n,
                size_bits=n * 16.0,  # bf16
                decode_flops_per_token=2.0 * na,
            )
        )
    return entries


def env_params_from_catalog(entries, **kwargs):
    """Paper-env parameters whose model sizes are the REAL catalogue sizes
    (clipped to edge-servable members — an ES cannot host llama3-405b)."""
    from repro.core import env as env_lib

    servable = [e for e in entries if e.param_count < 20e9]
    p = env_lib.default_params(num_models=len(servable), **kwargs)
    return p._replace(model_bits=tuple(e.size_bits for e in servable))
