"""Serving-side policy subsystem: trained MADDPG-MATO actors (and the
drain-aware greedy) behind ``route_batch``.

This module closes the loop between the four layers that previously never
touched: **training** (``core.maddpg`` / ``core.networks``),
**checkpointing** (``checkpoint.checkpointer``), the **batched router**
(``core.batch_router``) and the **serving driver** (``launch.serve``).
A checkpoint written by ``save_actor_checkpoint`` after a training run is
restored into a traceable policy callable that plugs straight into
``route_batch(policy=<callable>)`` — one jitted call still routes the
whole fleet.

Observation bridge (the heart of the subsystem)
-----------------------------------------------
A MADDPG-MATO actor was trained on the environment's per-agent eq. 16
observation (``core.env.observe``)::

    [ type one-hot K | x | rho | f_es N | compat N | own xy | es xy*N | cc xy | f_ed ]

The router carries a different native layout (``[resident, queue, flops]``
per server), so ``make_actor_policy`` rebuilds the eq. 16 row per request
from the fleet state the router already threads through its scan:

* ``type one-hot``   <- the request's tagged model index;
* ``x``              <- ``prompt_bits`` (the task payload);
* ``rho``            <- ``gen_tokens * flops_per_token / prompt_bits``
  (the request's compute density in FLOPs/bit, the serving analogue of
  the env's cycles/bit);
* ``f_es``           <- the candidate servers' ``flops_per_s``;
* ``compat``         <- live residency of the tagged model, **cell-masked**
  exactly like ``env.observe`` (out-of-cell servers read 0);
* positions / f_ed   <- static ``ObsDefaults`` (a serving fleet has no
  geometry; the defaults sit mid-distribution of the env's samplers).

Multi-cell transfer: ``cell_index_map`` precomputes, per request cell,
WHICH flat fleet columns the actor observes (and acts over):

* a policy trained at ``num_cells == 1`` with N servers serves a C-cell
  fleet of N servers per cell unchanged — each cell's servers are
  gathered into the actor's N observation slots;
* a policy trained at ``num_cells == C`` over N total servers serves the
  matching C-cell fleet — the actor sees all N servers with the compat
  columns cell-masked, exactly as during training.

Cloud-fallback columns (``CLOUD_CELL``) are never offered to the actor:
its action space is the env's {local, ES 1..N}, which has no cloud slot.
The actor's chosen ES maps back to a flat server index; serving always
places the request, so the ``local`` head is skipped.

Full eq. 16 action space
------------------------
The actor's output row is ``[target logits (N+1) | eta | beta]``. The
in-scan policy resolves the TARGET head live (residency drifts inside a
window); the continuous ``eta`` (partial-offload ratio, sigmoid as in
``maddpg._split_heads``) and binary ``beta`` (download decision,
``sigmoid > 0.5`` as executed by ``maddpg.policy_action``) must be
priced into the score matrix BEFORE routing, so
``actor_action_columns`` evaluates them once per window against the
window-entry residency snapshot and returns ``RequestBatch.eta`` /
``.beta`` columns. ``route_batch(..., actor=policy)`` plus those
columns serves the complete eq. 16 action ``(target, eta, beta)`` —
nothing from the trained head row is discarded anymore.

Checkpoint contract
-------------------
``save_actor_checkpoint`` stores the stacked actor pytree through the
atomic ``checkpoint.checkpointer`` and records the observation geometry
(``ObsSpec``) plus ``num_eds``/``hidden``/``model_aware`` in the manifest's
``extra`` dict, so ``load_actor_checkpoint`` can rebuild the parameter
template and the obs bridge with no side channel. ``launch.serve
--policy actor:<ckpt_dir>`` is exactly this restore path.
"""
from __future__ import annotations

import copy
import json
from pathlib import Path
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpointer
from repro.core import networks
from repro.core.router import CLOUD_CELL, ModelAwareRouter
from repro.core.types import MB_TO_BITS


class ObsSpec(NamedTuple):
    """Static geometry + normalisers of the eq. 16 observation the actor
    was trained on (everything ``build_obs`` needs, nothing else)."""

    num_models: int     # K — catalogue size == task types
    num_ess: int        # N — servers per actor decision (training fleet)
    num_cells: int      # C — training cell topology
    task_bits_hi: float  # x normaliser (env: task_mb_hi * MB_TO_BITS)
    rho_hi: float       # compute-density normaliser
    f_cc: float         # ES-capacity normaliser
    f_ed_hi: float      # device-capacity normaliser
    area_m: float       # position normaliser


def spec_from_env(p) -> ObsSpec:
    """ObsSpec of an ``EnvParams`` training setup."""
    return ObsSpec(
        num_models=p.num_models,
        num_ess=p.num_ess,
        num_cells=p.num_cells,
        task_bits_hi=p.task_mb_hi * MB_TO_BITS,
        rho_hi=p.rho_hi,
        f_cc=p.f_cc,
        f_ed_hi=p.f_ed_hi,
        area_m=p.area_m,
    )


def obs_dim(spec: ObsSpec) -> int:
    """Must equal ``env.obs_dim`` for the matching EnvParams (tested)."""
    return spec.num_models + 2 + 4 * spec.num_ess + 2 + 2 + 1


class ObsDefaults(NamedTuple):
    """Static stand-ins for the obs fields a serving fleet does not model
    (geometry, device capacity). Values sit mid-distribution of the env's
    samplers so a trained actor stays in-distribution."""

    ed_pos: jnp.ndarray   # (2,)
    es_pos: jnp.ndarray   # (n_es, 2)
    cc_pos: jnp.ndarray   # (2,)
    f_ed: jnp.ndarray     # ()


def default_obs_defaults(spec: ObsSpec) -> ObsDefaults:
    """Deterministic placement: ED at the area centre, ESs evenly spaced
    across the mid row, CC at the origin (as in ``env.reset``), device
    capacity at the env sampler's mean (U[f_lo, f_hi] with f_lo ~ hi/3)."""
    n = spec.num_ess
    xs = (jnp.arange(n, dtype=jnp.float32) + 1.0) / (n + 1.0) * spec.area_m
    es_pos = jnp.stack([xs, jnp.full((n,), 0.5 * spec.area_m)], axis=-1)
    return ObsDefaults(
        ed_pos=jnp.full((2,), 0.5 * spec.area_m),
        es_pos=es_pos,
        cc_pos=jnp.zeros((2,)),
        f_ed=jnp.asarray(2.0 / 3.0 * spec.f_ed_hi),
    )


def build_obs(spec: ObsSpec, *, model, x_bits, rho, f_es, compat,
              ed_pos, es_pos, cc_pos, f_ed) -> jnp.ndarray:
    """One eq. 16 observation row, field for field ``env.observe``'s layout.

    ``model``/``x_bits``/``rho``/``f_ed`` are scalars, ``f_es``/``compat``
    are (N,), positions are (2,)/(N, 2). The caller supplies ``compat``
    already cell-masked (see ``env.observe`` / ``make_actor_policy``).

    The per-request features (``x``, ``rho``) and the per-server
    capacity column (``f_es``) are clipped into the unit interval the
    actor saw during training: serving requests carry compute densities
    orders of magnitude beyond the env's ``rho_hi`` (decode FLOPs/token
    dwarf cycles/bit) and serving servers can out-muscle the training
    cloud's ``f_cc`` (the env's capacity normaliser), and unclipped
    either saturates the MLP and drowns the 0/1 compat signal. Inside
    the training ranges the clips are the identity, so this stays
    field-for-field ``env.observe``."""
    type_onehot = jax.nn.one_hot(model, spec.num_models)
    scalars = jnp.clip(jnp.stack([
        x_bits / spec.task_bits_hi,
        rho / spec.rho_hi,
    ]), 0.0, 1.0)
    return jnp.concatenate([
        type_onehot,
        scalars,
        jnp.clip(jnp.asarray(f_es) / spec.f_cc, 0.0, 1.0),
        jnp.asarray(compat, type_onehot.dtype),
        jnp.asarray(ed_pos) / spec.area_m,
        (jnp.asarray(es_pos) / spec.area_m).reshape(-1),
        jnp.asarray(cc_pos) / spec.area_m,
        jnp.asarray(f_ed)[None] / spec.f_ed_hi,
    ])


def cell_index_map(spec: ObsSpec, fleet_cell) -> tuple[np.ndarray, np.ndarray]:
    """Host-side (C, N) gather maps: which flat fleet columns the actor
    observes for a request in each cell.

    Returns ``(index_map, col_cell)`` — row ``c`` of ``index_map`` lists
    the server indices offered to cell-``c`` requests, ``col_cell`` their
    cell ids (for the env-style compat mask). Cloud columns
    (``CLOUD_CELL``) are excluded: the actor's action space has no cloud
    slot. Supported topologies:

    * trained single-cell (``spec.num_cells == 1``): every serving cell
      must hold exactly ``spec.num_ess`` edge servers; row ``c`` gathers
      cell ``c``'s servers;
    * matched topology (``spec.num_cells`` == serving cells, fleet-wide
      ``spec.num_ess`` edge servers total): every row is the full edge
      fleet, compat cell-masked exactly as in training.
    """
    cell = np.asarray(fleet_cell, np.int32)
    edge_idx = np.nonzero(cell != CLOUD_CELL)[0]
    cells = sorted(set(int(c) for c in cell[edge_idx]))
    if cells != list(range(len(cells))):
        raise ValueError(f"edge cell ids must be 0..C-1, got {cells}")
    n_cells = max(len(cells), 1)
    if spec.num_cells == n_cells and len(edge_idx) == spec.num_ess:
        rows = np.tile(edge_idx, (n_cells, 1))
    elif spec.num_cells == 1:
        rows = []
        for c in range(n_cells):
            members = edge_idx[cell[edge_idx] == c]
            if len(members) != spec.num_ess:
                raise ValueError(
                    f"cell {c} has {len(members)} edge servers; the actor "
                    f"was trained on num_ess={spec.num_ess}"
                )
            rows.append(members)
        rows = np.stack(rows)
    else:
        raise ValueError(
            f"cannot map an actor trained at num_cells={spec.num_cells}, "
            f"num_ess={spec.num_ess} onto a fleet with {n_cells} cells and "
            f"{len(edge_idx)} edge servers"
        )
    return rows.astype(np.int32), cell[rows]


def _agent_slice(stacked, agent: int):
    """One agent's MLP from the stacked (leading-axis) actor pytree."""
    return jax.tree.map(lambda x: jnp.asarray(x)[agent], stacked)


# ``optimization_barrier`` has no batching rule on this JAX, so the bare
# primitive breaks the mesh router's per-cell vmap; custom_vmap makes the
# barrier commute with vmap (it is the identity on values either way).
@jax.custom_batching.custom_vmap
def _fusion_barrier(x):
    return jax.lax.optimization_barrier(x)


@_fusion_barrier.def_vmap
def _fusion_barrier_vmap(axis_size, in_batched, x):
    return jax.lax.optimization_barrier(x), in_batched[0]


def make_actor_policy(actor_params, spec: ObsSpec, fleet_params, *,
                      agent: int = 0, defaults: Optional[ObsDefaults] = None,
                      model_aware: bool = True):
    """Turn (restored) stacked actor params into a ``route_batch`` policy.

    The returned callable follows the router's policy dispatch contract
    with ``needs_ctx = True`` (see ``core.batch_router``): per request it
    receives a ``PolicyCtx``, rebuilds the eq. 16 observation from the
    live fleet state, runs agent ``agent``'s MLP head and maps the argmax
    offload target back to a flat server index. Fully traceable — it runs
    inside the routing scan unchanged.
    """
    n_fleet = np.asarray(fleet_params.flops_per_s).shape[0]
    fleet_cell = (
        fleet_params.cell if fleet_params.cell is not None
        else np.zeros((n_fleet,), np.int32)
    )
    rows, row_cells = cell_index_map(spec, fleet_cell)
    index_map = jnp.asarray(rows)          # (C, N) flat server columns
    col_cell = jnp.asarray(row_cells)      # (C, N) their cell ids
    mlp = _agent_slice(actor_params, agent)
    dflt = defaults if defaults is not None else default_obs_defaults(spec)

    def _live_compat(ctx):
        c = jnp.int32(0) if ctx.cell is None else ctx.cell
        idx = index_map[c]                                   # (N,)
        # live residency of the tagged model, cell-masked like env.observe
        compat = ctx.resident[idx] & (col_cell[c] == c)
        if not model_aware:  # MADDPG-NoModel never sees the compat map
            compat = jnp.zeros_like(compat)
        return idx, compat

    def _decide(ctx):
        idx, compat = _live_compat(ctx)
        o = build_obs(
            spec,
            model=ctx.model,
            x_bits=ctx.prompt_bits,
            rho=ctx.gen_tokens * ctx.flops_tok / ctx.prompt_bits,
            f_es=ctx.params.flops_per_s[idx],
            compat=compat,
            ed_pos=dflt.ed_pos, es_pos=dflt.es_pos, cc_pos=dflt.cc_pos,
            f_ed=dflt.f_ed,
        )
        out = networks.mlp_apply(mlp, o)
        # head layout: [target logits (N+1) | eta | beta]; slot 0 is
        # "compute locally", which a routed request cannot do — serving
        # always places the request on the best ES head
        target = jnp.argmax(out[1: spec.num_ess + 1])
        return idx[target]

    def policy(lats, obs, queue, ctx):
        return _decide(ctx)

    n_ess = spec.num_ess
    # radius-1 compat variants: chunk-entry row + every single-bit flip.
    # MADDPG-NoModel's compat is identically zero — one variant suffices.
    flips = (np.concatenate([np.zeros((1, n_ess)), np.eye(n_ess)]) != 0
             if model_aware else np.zeros((1, n_ess), bool))
    flips = jnp.asarray(flips)                           # (V, N)

    def _obs_rows(cctx, idx, compat):
        """Batched eq. 16 observation build; ``compat`` may carry extra
        leading axes beyond the chunk axis (the variant axis below)."""
        row = lambda model, x_bits, rho, f_es, cm: build_obs(
            spec, model=model, x_bits=x_bits, rho=rho, f_es=f_es,
            compat=cm, ed_pos=dflt.ed_pos, es_pos=dflt.es_pos,
            cc_pos=dflt.cc_pos, f_ed=dflt.f_ed,
        )
        for _ in range(compat.ndim - 2):  # map the variant axis too
            row = jax.vmap(row, in_axes=(None, None, None, None, 0))
        return jax.vmap(row)(
            cctx.model, cctx.prompt_bits,
            cctx.gen_tokens * cctx.flops_tok / cctx.prompt_bits,
            cctx.params.flops_per_s[idx], compat)

    def chunk_precompute(cctx):
        """Chunk-level hook (``core.batch_router``): batch the eq. 16
        observation build AND the actor MLP over the whole chunk — one
        MXU contraction instead of c per-request matvecs.

        The actor reads the live fleet state ONLY through the n-bit
        compat row, and inside one chunk that row almost never drifts
        more than one bit from its chunk-entry value (a drift means some
        earlier request in the chunk loaded/evicted THIS request's
        tagged model inside THIS request's cell). So we price n+1
        residency variants per request — the entry row plus every
        single-bit flip — and the per-step hook becomes a table lookup;
        only a multi-bit drift replays the full per-request decision."""
        cells = (jnp.zeros_like(cctx.model) if cctx.cell is None
                 else cctx.cell)
        idx = index_map[cells]                               # (c, N)
        cell_ok = col_cell[cells] == cells[:, None]          # (c, N)
        # chunk-entry residency of each request's tagged model
        entry = jnp.take_along_axis(
            cctx.resident.T[cctx.model], idx, axis=1) & cell_ok
        if not model_aware:
            entry = jnp.zeros_like(entry)
        # live compat stays inside the cell mask, so masked flip
        # variants are unreachable duplicates — harmless
        compat = (entry[:, None, :] ^ flips[None, :, :]) \
            & cell_ok[:, None, :]                            # (c, V, N)
        # barrier: keep the concat-built obs rows OUT of the matmul
        # fusion — fused, XLA lowers the contraction as a loop nest
        # instead of one gemm call (measured ~4x slower end to end)
        rows = _fusion_barrier(_obs_rows(cctx, idx, compat))
        out = networks.mlp_apply(mlp, rows)
        target = jnp.argmax(out[..., 1: n_ess + 1], axis=-1)  # (c, V)
        choice = jnp.take_along_axis(idx, target, axis=1)    # (c, V)
        # idx/cell_ok ride along so the per-step resolve skips the
        # (state-independent) index_map/col_cell gathers
        return choice, entry, idx, cell_ok

    def chunk_apply(aux_b, ctx):
        """Resolve one request from its precomputed decisions: index the
        variant table by how the live compat row differs from the
        chunk-entry row it was priced against (0 bits -> entry variant,
        1 bit -> that flip's variant). A >=2-bit drift — rare, the
        chunk must churn the same (cell, model) residency row twice
        before this request's turn — is reported as inexact and the
        router replays the chunk through the per-request path."""
        table_b, entry, idx, cell_ok = aux_b
        compat = ctx.resident[idx] & cell_ok
        if not model_aware:
            compat = jnp.zeros_like(compat)
        diff = compat != entry
        d = jnp.sum(diff)
        k = jnp.where(d == 0, 0, 1 + jnp.argmax(diff)).astype(jnp.int32)
        return table_b[jnp.minimum(k, table_b.shape[0] - 1)], d <= 1

    policy.needs_obs = False
    policy.needs_ctx = True
    policy.chunk_precompute = chunk_precompute
    policy.chunk_apply = chunk_apply
    return policy


def actor_action_columns(actor_params, spec: ObsSpec, fleet_params, state,
                         reqs, *, agent: int = 0,
                         defaults: Optional[ObsDefaults] = None,
                         model_aware: bool = True):
    """Evaluate the actor's eta/beta heads for one request window.

    The eq. 16 action is ``(target, eta, beta)``; ``make_actor_policy``
    resolves the target head inside the routing scan, but the offload
    ratio and the download decision reshape the score matrix itself
    (eq. 5/9 scaling, eq. 7 gating) and so must be fixed per request
    BEFORE routing. This evaluates agent ``agent``'s MLP once over the
    window — same observation bridge as the in-scan policy, residency
    read from the WINDOW-ENTRY ``state`` — and squashes the two trailing
    heads exactly as training executes them (``maddpg.policy_action``
    sans exploration): ``eta = sigmoid``, ``beta = sigmoid(.) > 0.5``,
    beta forced off for MADDPG-NoModel.

    Returns ``(eta, beta)`` ready for ``RequestBatch``; route with::

        eta, beta = actor_action_columns(params, spec, fp, state, reqs)
        reqs = reqs._replace(eta=eta, beta=beta)
        route_batch(fp, state, reqs, policy=actor_policy)
    """
    n_fleet = np.asarray(fleet_params.flops_per_s).shape[0]
    fleet_cell = (
        fleet_params.cell if fleet_params.cell is not None
        else np.zeros((n_fleet,), np.int32)
    )
    rows, row_cells = cell_index_map(spec, fleet_cell)
    index_map = jnp.asarray(rows)
    col_cell = jnp.asarray(row_cells)
    mlp = _agent_slice(actor_params, agent)
    dflt = defaults if defaults is not None else default_obs_defaults(spec)

    model = jnp.asarray(reqs.model)
    cells = jnp.zeros_like(model) if reqs.cell is None else reqs.cell
    idx = index_map[cells]                                   # (B, N)
    cell_ok = col_cell[cells] == cells[:, None]              # (B, N)
    resident = jnp.asarray(state.resident)
    compat = jnp.take_along_axis(resident.T[model], idx, axis=1) & cell_ok
    if not model_aware:
        compat = jnp.zeros_like(compat)
    flops_tok = jnp.asarray(fleet_params.decode_flops_per_token)[model]
    row = lambda m, x, r, f, cm: build_obs(
        spec, model=m, x_bits=x, rho=r, f_es=f, compat=cm,
        ed_pos=dflt.ed_pos, es_pos=dflt.es_pos, cc_pos=dflt.cc_pos,
        f_ed=dflt.f_ed,
    )
    obs = jax.vmap(row)(
        model, reqs.prompt_bits,
        reqs.gen_tokens * flops_tok / reqs.prompt_bits,
        jnp.asarray(fleet_params.flops_per_s)[idx], compat,
    )
    out = networks.mlp_apply(mlp, obs)                       # (B, N+3)
    eta = jax.nn.sigmoid(out[..., spec.num_ess + 1])
    beta = jax.nn.sigmoid(out[..., spec.num_ess + 2]) > 0.5
    if not model_aware:  # download action forced off, as in training
        beta = jnp.zeros_like(beta)
    return eta, beta


# ---------------------------------------------------------------------------
# checkpoint round-trip
# ---------------------------------------------------------------------------
def save_actor_checkpoint(ckpt_dir, actor_params, p, cfg, *, step: int = 0,
                          keep: int = 3) -> Path:
    """Persist trained actor params + the obs geometry needed to serve them.

    ``p`` is the training ``EnvParams``, ``cfg`` the ``AlgoConfig``; both
    are reduced to plain scalars in the manifest's ``extra`` dict so the
    restore side needs no pickle and no source-of-truth beyond the
    checkpoint directory."""
    spec = spec_from_env(p)
    num_eds = int(np.asarray(jax.tree.leaves(actor_params)[0]).shape[0])
    extra = {
        "kind": "maddpg-actor",
        "num_eds": num_eds,
        "hidden": int(cfg.hidden),
        "model_aware": bool(cfg.model_aware),
        "spec": {k: (int(v) if isinstance(v, int) else float(v))
                 for k, v in spec._asdict().items()},
    }
    return checkpointer.save(ckpt_dir, step, actor_params, keep=keep,
                             extra=extra)


def load_actor_checkpoint(ckpt_dir, step: Optional[int] = None):
    """Restore ``(actor_params, ObsSpec, extra)`` from a checkpoint dir.

    The parameter template is rebuilt from the manifest metadata
    (``num_eds`` x MLP sizes), so this works in a fresh process with no
    access to the original ``EnvParams``/``AlgoConfig`` objects."""
    if step is None:
        step = checkpointer.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    manifest = json.loads(
        (Path(ckpt_dir) / f"step_{step}" / "manifest.json").read_text()
    )
    extra = manifest["extra"]
    if extra.get("kind") != "maddpg-actor":
        raise ValueError(f"{ckpt_dir} step {step} is not an actor checkpoint")
    spec = ObsSpec(**extra["spec"])
    sizes = [obs_dim(spec), extra["hidden"], extra["hidden"],
             spec.num_ess + 1 + 2]
    like = networks.stacked_init(jax.random.key(0), extra["num_eds"], sizes)
    params, extra = checkpointer.restore(ckpt_dir, step, like)
    return params, spec, extra


def load_actor_policy(ckpt_dir, fleet_params, *, step: Optional[int] = None,
                      agent: int = 0):
    """One-call serve path: checkpoint dir -> ``route_batch`` policy."""
    params, spec, extra = load_actor_checkpoint(ckpt_dir, step)
    return make_actor_policy(
        params, spec, fleet_params, agent=agent,
        model_aware=extra.get("model_aware", True),
    )


def actor_policy_for_cell_blocks(actor_params, spec: ObsSpec, fleet_params,
                                 **kwargs):
    """Actor policy for the cell-major sharded router: ONE policy closure
    that serves EVERY cell block of ``core.mesh_router.route_batch_sharded``.

    Under the mesh the per-request ``PolicyCtx`` carries a LOCAL view — a
    single cell's server block (relabelled cell 0) plus the shared cloud
    columns — so the flat index map baked by ``make_actor_policy`` must be
    built against that local geometry, not the global fleet. Since the
    actor reads the fleet ONLY through live ctx values (residency, queue,
    flops all flow through ``PolicyCtx``; ``fleet_params`` fixes nothing
    but index geometry), the closure built on block 0's template is
    bitwise-correct for every other equal-size block too.

    Requires a single-cell-trained actor (``spec.num_cells == 1``) whose
    ``spec.num_ess`` matches the fleet's per-cell block size — the only
    topology where all blocks share one index map. The matched-topology
    mode of ``cell_index_map`` (actor sees ALL cells at once) cannot be
    served from per-cell shards; route those fleets unsharded.
    """
    from repro.core import batch_router as br

    layout = br.cell_layout(fleet_params)
    if spec.num_cells != 1:
        raise ValueError(
            f"sharded serving needs a single-cell-trained actor "
            f"(spec.num_cells == 1, one index map shared by every block); "
            f"got num_cells={spec.num_cells} — route this fleet unsharded"
        )
    if spec.num_ess != layout.per_cell:
        raise ValueError(
            f"actor was trained on num_ess={spec.num_ess} edge servers but "
            f"the fleet's cell blocks hold {layout.per_cell}"
        )
    local = br.local_block_params(fleet_params, layout, 0)
    return make_actor_policy(actor_params, spec, local, **kwargs)


# ---------------------------------------------------------------------------
# policy evaluation: drain-corrected realized latency
# ---------------------------------------------------------------------------
def drain_corrected_latencies(servers, catalog, requests, choices):
    """Reprice a routed stream under the drain-corrected cost model.

    The eq. 11 latency ``route_batch`` reports prices the queue backlog
    as pure compute (eq. 9) — a BIASED estimate whenever the fleet has a
    continuous ``drain_rate``, because the simulated queues genuinely
    decay between arrivals. This replays ``(requests, choices)`` through
    the scalar oracle (same commits, same wall clock) but records each
    request's latency with the backlog term discounted the way the drain
    policy prices it (``q*ftok/(f + r*ftok)``): the model-consistent
    realized latency. Comparing policies on THIS number is the fair
    fight — on raw eq. 11, greedy is the argmin of the metric itself.

    Requests carrying the eq. 16 knobs replay them: ``eta`` scales the
    edge share inside ``_candidate_latency`` and the recorded number is
    the eq. 13 max with the device's retained share (``_local_latency``
    is 0.0 for knob-free requests, so full-offload streams are priced
    exactly as before).

    ``choices`` must be feasible (no ``-1`` rejections). Returns a float
    list aligned with ``requests``.
    """
    script = iter(int(c) for c in choices)
    router = ModelAwareRouter(copy.deepcopy(servers), catalog,
                              policy="actor",
                              actor=lambda obs, lats: next(script))
    corrected = []
    for req, choice in zip(requests, choices):
        if choice < 0:
            raise ValueError("drain_corrected_latencies needs feasible "
                             "choices (got a rejection)")
        if req.arrival_s is not None:  # idempotent: route() advances again
            router.advance_time(req.arrival_s)
        srv = router.servers[int(choice)]
        lat = router._candidate_latency(srv, req)
        corrected.append(max(router._local_latency(req),
                             router._drain_score(srv, req, lat)))
        router.route(req)
    return corrected
