"""MADDPG-MATO — Multi-Agent DDPG, Model-Aware Task Offloading (paper §III).

Each ED is an agent: actor ``v_m(o_m)`` emits (offload-target logits,
eta, beta); a centralised critic ``Q_m(s, a_1..a_M)`` scores joint
actions (eqs. 19-23). Per-agent networks are stacked pytrees vmapped over
the agent axis. The full training loop — vectorised env rollout, replay,
periodic batched updates, soft target updates — is ONE jitted
``lax.scan``; no host round-trips.

Flags reproduce the paper's learned baselines:
  * ``centralized_critic=False``  -> SADDPG (independent DDPG per ED)
  * ``model_aware=False``         -> MADDPG-NoModel (compatibility masked
     from observations; download action forced off)
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import env as env_lib
from repro.core import networks, replay
from repro.core.types import Action, EnvParams, action_dim, flat_action
from repro.optim import adamw
from repro.optim.adamw import apply_updates


class AlgoConfig(NamedTuple):
    hidden: int = 128
    critic_hidden: int = 256
    lr_actor: float = 1e-3        # paper: 0.001
    lr_critic: float = 1e-3
    gamma: float = 0.95           # paper: 0.95
    tau: float = 0.01             # paper: 0.01
    buffer_capacity: int = 10000  # paper: 10,000
    batch_size: int = 1024        # paper: 1024
    update_every: int = 10
    warmup: int = 1500
    explore_sigma: float = 0.15
    gumbel_scale: float = 1.0
    explore_decay_steps: int = 8000
    n_envs: int = 4
    total_steps: int = 12000
    centralized_critic: bool = True
    model_aware: bool = True


class TrainState(NamedTuple):
    actor: list
    critic: list
    target_actor: list
    target_critic: list
    actor_opt: object
    critic_opt: object
    step: jnp.ndarray


def _mask_obs(obs, p: EnvParams, model_aware: bool):
    """MADDPG-NoModel cannot observe d_{m,i,n} (paper §IV.A)."""
    if model_aware:
        return obs
    k, n = p.num_models, p.num_ess
    start = k + 2 + n  # [type K | x | rho | f_es N | compat N | ...]
    mask = jnp.ones((obs.shape[-1],)).at[start : start + n].set(0.0)
    return obs * mask


def actor_sizes(p: EnvParams, cfg: AlgoConfig):
    return [env_lib.obs_dim(p), cfg.hidden, cfg.hidden, p.num_ess + 1 + 2]


def critic_in_dim(p: EnvParams, cfg: AlgoConfig):
    a = action_dim(p.num_ess)
    if cfg.centralized_critic:
        return p.num_eds * env_lib.obs_dim(p) + env_lib.global_dim(p) + p.num_eds * a
    return env_lib.obs_dim(p) + a


def critic_sizes(p: EnvParams, cfg: AlgoConfig):
    return [critic_in_dim(p, cfg), cfg.critic_hidden, cfg.critic_hidden, 1]


def init_state(key, p: EnvParams, cfg: AlgoConfig) -> TrainState:
    m = p.num_eds
    k_a, k_c = jax.random.split(key)
    actor = networks.stacked_init(k_a, m, actor_sizes(p, cfg), final_scale=0.1)
    critic = networks.stacked_init(k_c, m, critic_sizes(p, cfg), final_scale=0.1)
    a_init, _ = _actor_opt(cfg)
    c_init, _ = _critic_opt(cfg)
    return TrainState(
        actor=actor,
        critic=critic,
        target_actor=jax.tree.map(jnp.copy, actor),
        target_critic=jax.tree.map(jnp.copy, critic),
        actor_opt=a_init(actor),
        critic_opt=c_init(critic),
        step=jnp.int32(0),
    )


@functools.lru_cache(maxsize=None)
def _opt_pair(lr):
    return adamw(lr)


def _actor_opt(cfg):
    return _opt_pair(cfg.lr_actor)


def _critic_opt(cfg):
    return _opt_pair(cfg.lr_critic)


# --- action heads -------------------------------------------------------------
def _split_heads(out, num_ess: int):
    logits = out[..., : num_ess + 1]
    eta = jax.nn.sigmoid(out[..., num_ess + 1])
    beta = jax.nn.sigmoid(out[..., num_ess + 2])
    return logits, eta, beta


def policy_action(actor, obs, p: EnvParams, cfg: AlgoConfig, key, explore_scale):
    """Executed (discrete) action with exploration noise."""
    out = networks.stacked_apply(actor, obs)  # (M, A_out)
    logits, eta, beta = _split_heads(out, p.num_ess)
    k_g, k_e, k_b = jax.random.split(key, 3)
    gumbel = jax.random.gumbel(k_g, logits.shape) * cfg.gumbel_scale
    target = jnp.argmax(logits + gumbel * explore_scale, axis=-1).astype(jnp.int32)
    eta = jnp.clip(
        eta + explore_scale * cfg.explore_sigma * jax.random.normal(k_e, eta.shape),
        0.0,
        1.0,
    )
    beta_prob = jnp.clip(
        beta + explore_scale * cfg.explore_sigma * jax.random.normal(k_b, beta.shape),
        0.0,
        1.0,
    )
    beta_exec = (beta_prob > 0.5).astype(jnp.float32)
    if not cfg.model_aware:
        beta_exec = jnp.zeros_like(beta_exec)
    return Action(target=target, eta=eta, beta=beta_exec)


def _soft_action(actor, obs, p: EnvParams, cfg: AlgoConfig):
    """Differentiable relaxed action vector (softmax over targets)."""
    out = networks.stacked_apply(actor, obs)
    logits, eta, beta = _split_heads(out, p.num_ess)
    probs = jax.nn.softmax(logits / cfg.gumbel_scale, axis=-1)
    if not cfg.model_aware:
        beta = jnp.zeros_like(beta)
    return jnp.concatenate([probs, eta[..., None], beta[..., None]], axis=-1)


# --- critic featurisation -----------------------------------------------------
def _critic_inputs(obs, gstate, acts, p: EnvParams, cfg: AlgoConfig):
    """Build the (M, B, X) critic input tensor.

    obs: (B, M, D)   gstate: (B, G)   acts: (B, M, A) or (M, B, M, A) for
    the per-agent actor-loss variant.
    """
    m = p.num_eds
    b = obs.shape[0]
    if cfg.centralized_critic:
        obs_flat = obs.reshape(b, -1)
        if acts.ndim == 3:
            act_flat = jnp.broadcast_to(
                acts.reshape(b, -1)[None], (m, b, m * acts.shape[-1])
            )
        else:  # (M, B, M, A) — per-agent replaced joint actions
            act_flat = acts.reshape(m, b, -1)
        base = jnp.concatenate([obs_flat, gstate], axis=-1)
        base = jnp.broadcast_to(base[None], (m, b, base.shape[-1]))
        return jnp.concatenate([base, act_flat], axis=-1)
    # SADDPG: own obs + own action only
    own_obs = jnp.swapaxes(obs, 0, 1)  # (M, B, D)
    if acts.ndim == 3:
        own_act = jnp.swapaxes(acts, 0, 1)
    else:
        own_act = acts[jnp.arange(m), :, jnp.arange(m), :]
    return jnp.concatenate([own_obs, own_act], axis=-1)


# --- one gradient update -------------------------------------------------------
def update(ts: TrainState, batch, key, p: EnvParams, cfg: AlgoConfig) -> TrainState:
    obs, acts = batch["obs"], batch["act"]
    rew, done = batch["rew"], batch["done"]
    nobs, gstate, ngstate = batch["next_obs"], batch["gstate"], batch["next_gstate"]
    m = p.num_eds

    # ---- critic target (eq. 19) ----
    next_act = jax.vmap(lambda o: _soft_action(ts.target_actor, o, p, cfg))(
        nobs
    )  # (B, M, A)
    next_in = _critic_inputs(nobs, ngstate, next_act, p, cfg)
    q_next = networks.stacked_apply(ts.target_critic, next_in)[..., 0]  # (M, B)
    y = jnp.swapaxes(rew, 0, 1) + cfg.gamma * (1.0 - done)[None, :] * q_next

    # ---- critic loss (eq. 20) ----
    def critic_loss_fn(critic):
        q = networks.stacked_apply(
            critic, _critic_inputs(obs, gstate, acts, p, cfg)
        )[..., 0]
        return jnp.mean(jnp.square(q - jax.lax.stop_gradient(y)))

    c_loss, c_grads = jax.value_and_grad(critic_loss_fn)(ts.critic)
    _, c_upd_fn = _critic_opt(cfg)
    c_updates, c_opt = c_upd_fn(c_grads, ts.critic_opt, ts.critic)
    critic = apply_updates(ts.critic, c_updates)

    # ---- actor loss (eq. 21): replace own slot with current-policy action ----
    def actor_loss_fn(actor):
        cur = jax.vmap(lambda o: _soft_action(actor, o, p, cfg))(obs)  # (B, M, A)
        # joint actions per agent: (M, B, M, A); agent i's slot i is the
        # differentiable current-policy action, others come from the batch.
        eye = jnp.eye(m, dtype=bool)[:, None, :, None]
        batch_joint = jnp.broadcast_to(acts[None], (m,) + acts.shape)
        cur_b = jnp.broadcast_to(cur[None], (m,) + cur.shape)
        joint = jnp.where(eye, cur_b, batch_joint)  # (M, B, M, A)
        q = networks.stacked_apply(
            critic, _critic_inputs(obs, gstate, joint, p, cfg)
        )[..., 0]
        return -jnp.mean(q)

    a_loss, a_grads = jax.value_and_grad(actor_loss_fn)(ts.actor)
    _, a_upd_fn = _actor_opt(cfg)
    a_updates, a_opt = a_upd_fn(a_grads, ts.actor_opt, ts.actor)
    actor = apply_updates(ts.actor, a_updates)

    # ---- soft target updates (eqs. 22-23) ----
    return TrainState(
        actor=actor,
        critic=critic,
        target_actor=networks.soft_update(ts.target_actor, actor, cfg.tau),
        target_critic=networks.soft_update(ts.target_critic, critic, cfg.tau),
        actor_opt=a_opt,
        critic_opt=c_opt,
        step=ts.step + 1,
    )


# --- full training loop ---------------------------------------------------------
def make_transition_example(p: EnvParams, cfg: AlgoConfig):
    d, g, a = env_lib.obs_dim(p), env_lib.global_dim(p), action_dim(p.num_ess)
    m = p.num_eds
    z = jnp.zeros
    return {
        "obs": z((m, d)), "act": z((m, a)), "rew": z((m,)),
        "next_obs": z((m, d)), "done": z(()), "gstate": z((g,)),
        "next_gstate": z((g,)),
    }


def train(key, p: EnvParams, cfg: AlgoConfig):
    """Returns (TrainState, metrics dict of per-step arrays)."""
    k_init, k_env, k_loop = jax.random.split(key, 3)
    ts = init_state(k_init, p, cfg)
    env_keys = jax.random.split(k_env, cfg.n_envs)
    env_states = jax.vmap(lambda k: env_lib.reset(k, p))(env_keys)
    buf = replay.init(cfg.buffer_capacity, make_transition_example(p, cfg))

    obs0 = jax.vmap(lambda s: env_lib.observe(s, p))(env_states)

    def scan_step(carry, step_idx):
        ts, env_states, obs, buf, key = carry
        key, k_act, k_upd = jax.random.split(key, 3)
        explore = jnp.maximum(0.05, 1.0 - step_idx / cfg.explore_decay_steps)

        obs_in = _mask_obs(obs, p, cfg.model_aware)
        act_keys = jax.random.split(k_act, cfg.n_envs)
        actions = jax.vmap(
            lambda o, k: policy_action(ts.actor, o, p, cfg, k, explore)
        )(obs_in, act_keys)

        gstate = jax.vmap(lambda s: env_lib.global_state(s, p))(env_states)
        nxt, nobs, outcome, done = jax.vmap(lambda s, a: env_lib.step(s, a, p))(
            env_states, actions
        )
        ngstate = jax.vmap(lambda s: env_lib.global_state(s, p))(nxt)
        nobs_in = _mask_obs(nobs, p, cfg.model_aware)

        items = {
            "obs": obs_in,
            "act": jax.vmap(lambda a: flat_action(a, p.num_ess))(actions),
            "rew": outcome.reward,
            "next_obs": nobs_in,
            "done": done.astype(jnp.float32),
            "gstate": gstate,
            "next_gstate": ngstate,
        }
        buf = replay.add_batch(buf, items, cfg.n_envs)

        do_upd = (step_idx % cfg.update_every == 0) & (buf.size >= cfg.warmup)
        k_s, k_u = jax.random.split(k_upd)
        batch = replay.sample(buf, k_s, cfg.batch_size)
        ts = jax.lax.cond(
            do_upd, lambda t: update(t, batch, k_u, p, cfg), lambda t: t, ts
        )

        env_states = jax.vmap(lambda s, d: env_lib.auto_reset(s, d, p))(nxt, done)
        obs = jax.vmap(lambda s: env_lib.observe(s, p))(env_states)

        metrics = {
            "reward": outcome.reward.sum(-1).mean(),
            "latency": outcome.latency.mean(),
            "energy": outcome.energy.mean(),
            "completion": outcome.completed.mean(),
        }
        return (ts, env_states, obs, buf, key), metrics

    (ts, *_), metrics = jax.lax.scan(
        scan_step, (ts, env_states, obs0, buf, k_loop), jnp.arange(cfg.total_steps)
    )
    return ts, metrics


train_jit = jax.jit(train, static_argnums=(1, 2))
