"""Composable, RNG-keyed traffic primitives for IIoT workload synthesis.

Every primitive is a pure function of an explicit ``numpy.random.
Generator`` (PCG64 — bit-identical across processes and platforms), so
any stream regenerates exactly from ``(spec, seed)``. Components that
must stay independent of each other's draw counts take SEPARATE child
generators spawned from one ``SeedSequence`` (``component_rngs``) — the
numpy analogue of ``jax.random.split``; components that must reproduce
a legacy sequentially-consumed stream (``benchmarks/policy_serving.py``'s
bursty fixture) share one generator in the canonical draw order
(``stream_fields``).

The outputs are plain arrays shaped for the jitted serving plane:
``to_request_batch`` packs them into a ``core.batch_router.
RequestBatch`` (struct-of-arrays, ``float32``/``int32``) that feeds
``route_batch``/``vmap`` directly.

Arrival processes
-----------------
All return a non-decreasing ``(n,)`` float array of wall-clock arrival
stamps (seconds). The inhomogeneous ones share one construction: a
unit-rate Poisson mass ``u_i = cumsum(Exp(1))`` time-warped through the
inverse cumulative rate ``t_i = Lambda^{-1}(u_i)`` — exact for
piecewise-constant rates (MMPP, flash crowd), grid-interpolated for the
smooth diurnal sinusoid.

  * ``poisson_arrivals``      — homogeneous rate ``r``
  * ``burst_train_arrivals``  — deterministic burst train with jitter
    (the legacy ``policy_serving`` fixture)
  * ``mmpp_arrivals``         — two-state Markov-modulated Poisson
    (quiet/burst sojourns, exponentially distributed dwell times)
  * ``diurnal_arrivals``      — sinusoid-modulated rate (a scaled-down
    day/night cycle)
  * ``flash_crowd_arrivals``  — baseline rate with one multiplicative
    spike window

Popularity / skew / lengths
---------------------------
  * ``zipf_popularity``      — Zipf(s) over K model ranks (s=0: uniform)
  * ``drifting_popularity``  — Zipf masses re-assigned to models by a
    fresh random rank permutation per time window: residency churn as a
    tunable knob (the drift period)
  * ``hotspot_cell_probs``   — one cell absorbs a fixed traffic share
  * ``sample_models`` / ``sample_cells`` / ``sample_prompt_bits`` /
    ``sample_gen_tokens`` — the per-request columns
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np


def component_rngs(seed: int, n: int) -> list[np.random.Generator]:
    """``n`` independent child generators spawned from one seed.

    Spawning (rather than sequential consumption) keeps each component's
    stream independent of how many draws the others make — changing the
    arrival process can never silently reshuffle the model column."""
    return [np.random.default_rng(s)
            for s in np.random.SeedSequence(seed).spawn(n)]


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------
def unit_poisson_mass(rng: np.random.Generator, n: int) -> np.ndarray:
    """Cumulative mass of a unit-rate Poisson process: ``cumsum(Exp(1))``."""
    return np.cumsum(rng.exponential(1.0, n))


def warp_piecewise_rate(mass, starts, rates) -> np.ndarray:
    """Invert the cumulative rate of a piecewise-constant intensity.

    ``starts[i]`` is where segment ``i`` (intensity ``rates[i]``) begins;
    the LAST segment is unbounded. Returns ``t`` with
    ``Lambda(t) == mass`` — exact, monotone, vectorised."""
    starts = np.asarray(starts, float)
    rates = np.asarray(rates, float)
    cum = np.concatenate([[0.0], np.cumsum(rates[:-1] * np.diff(starts))])
    seg = np.clip(np.searchsorted(cum, mass, side="right") - 1,
                  0, len(rates) - 1)
    return starts[seg] + (mass - cum[seg]) / rates[seg]


def poisson_arrivals(rng: np.random.Generator, n: int,
                     rate: float) -> np.ndarray:
    """Homogeneous Poisson arrivals at ``rate`` requests/second."""
    return np.cumsum(rng.exponential(1.0 / rate, n))


def burst_train_arrivals(rng: np.random.Generator, n: int, burst: int,
                         gap_s: float, jitter_s: float = 1e-3) -> np.ndarray:
    """Bursts of ``burst`` near-simultaneous requests every ``gap_s``
    seconds (uniform ``jitter_s`` spread within a burst) — the arrival
    pattern where queue-drain awareness matters."""
    arrivals = (np.arange(n) // burst) * gap_s + rng.uniform(0.0, jitter_s, n)
    return np.sort(arrivals)


def mmpp_arrivals(rng: np.random.Generator, n: int, rate_lo: float,
                  rate_hi: float, dwell_lo_s: float,
                  dwell_hi_s: float) -> np.ndarray:
    """Two-state Markov-modulated Poisson process: the intensity
    alternates between a quiet state (``rate_lo``, mean sojourn
    ``dwell_lo_s``) and a burst state (``rate_hi``, ``dwell_hi_s``),
    sojourns exponentially distributed. Sojourns are drawn until their
    cumulative mass covers ``n`` arrivals, then the unit-rate mass is
    warped through the piecewise-constant intensity."""
    mass = unit_poisson_mass(rng, n)
    starts, rates = [0.0], []
    t, covered, lo = 0.0, 0.0, True
    while covered < mass[-1]:
        dwell, rate = (dwell_lo_s, rate_lo) if lo else (dwell_hi_s, rate_hi)
        d = rng.exponential(dwell)
        t += d
        covered += rate * d
        starts.append(t)
        rates.append(rate)
        lo = not lo
    rates.append(rate_lo)  # unbounded tail segment (covers the == corner)
    return warp_piecewise_rate(mass, starts, rates)


def diurnal_arrivals(rng: np.random.Generator, n: int, rate: float,
                     period_s: float, depth: float) -> np.ndarray:
    """Sinusoid-modulated arrivals: intensity
    ``rate * (1 + depth * sin(2 pi t / period))`` (``0 <= depth < 1``).
    The closed-form cumulative rate is inverted on a dense grid."""
    mass = unit_poisson_mass(rng, n)
    horizon = mass[-1] / rate + 2.0 * period_s  # Lambda(horizon) > mass[-1]
    grid = np.linspace(0.0, horizon, max(2048, int(256 * horizon / period_s)))
    w = 2.0 * np.pi / period_s
    cum = rate * (grid + depth / w * (1.0 - np.cos(w * grid)))
    return np.interp(mass, cum, grid)


def flash_crowd_arrivals(rng: np.random.Generator, n: int, rate: float,
                         spike_start_s: float, spike_dur_s: float,
                         spike_mult: float) -> np.ndarray:
    """Baseline Poisson at ``rate`` with one flash-crowd window of
    ``spike_mult`` x intensity in ``[spike_start_s, spike_start_s +
    spike_dur_s)``."""
    mass = unit_poisson_mass(rng, n)
    starts = [0.0, spike_start_s, spike_start_s + spike_dur_s]
    rates = [rate, rate * spike_mult, rate]
    return warp_piecewise_rate(mass, starts, rates)


# ---------------------------------------------------------------------------
# popularity / skew
# ---------------------------------------------------------------------------
def zipf_popularity(num_models: int, s: float) -> np.ndarray:
    """Zipf(s) probabilities over ``num_models`` ranks (sums to 1;
    ``s = 0`` is uniform). Index = rank: entry 0 is the most popular."""
    w = np.arange(1, num_models + 1, dtype=float) ** -float(s)
    return w / w.sum()


def drifting_popularity(rng: np.random.Generator, num_windows: int,
                        num_models: int, s: float):
    """Time-drifting Zipf: one fresh random rank order per window.

    Returns ``(probs, perms)``: ``probs[w, m]`` is model ``m``'s mass in
    window ``w`` (each row sums to 1 — the same Zipf(s) masses
    re-assigned), ``perms[w, r]`` the model holding rank ``r``. The
    window length (the caller's drift period) is the residency-churn
    knob: shorter windows force more eq. 7 model switches."""
    base = zipf_popularity(num_models, s)
    perms = np.argsort(rng.random((num_windows, num_models)), axis=1)
    probs = np.zeros((num_windows, num_models))
    np.put_along_axis(probs, perms,
                      np.broadcast_to(base, perms.shape), axis=1)
    return probs, perms


def hotspot_cell_probs(num_cells: int, hotspot_cell: int,
                       hotspot_weight: float) -> np.ndarray:
    """Cell distribution where ``hotspot_cell`` absorbs
    ``hotspot_weight`` of the traffic and the rest split uniformly."""
    if num_cells == 1:
        return np.ones(1)
    p = np.full(num_cells, (1.0 - hotspot_weight) / (num_cells - 1))
    p[hotspot_cell] = hotspot_weight
    return p


def sample_categorical(rng: np.random.Generator, n: int, probs,
                       rows: Optional[np.ndarray] = None) -> np.ndarray:
    """Inverse-CDF draws from ``probs`` — ``(K,)``, or ``(W, K)`` with
    ``rows`` giving each request's window id."""
    p = np.asarray(probs, float)
    u = rng.random(n)
    if p.ndim == 1:
        cdf = np.cumsum(p)
        return np.searchsorted(cdf, u * cdf[-1], side="right").astype(np.int64)
    cdf = np.cumsum(p, axis=1)[rows]                       # (n, K)
    return (cdf < u[:, None] * cdf[:, -1:]).sum(axis=1)


# ---------------------------------------------------------------------------
# per-request columns (canonical draw order: model, prompt, gen, cell)
# ---------------------------------------------------------------------------
def sample_models(rng: np.random.Generator, n: int, num_models: int,
                  probs=None, rows: Optional[np.ndarray] = None) -> np.ndarray:
    """Model column: uniform (``probs=None``) or popularity-weighted,
    optionally per-window (``rows``) for drifting popularity."""
    if probs is None:
        return rng.integers(0, num_models, n)
    return sample_categorical(rng, n, probs, rows)


def sample_prompt_bits(rng: np.random.Generator, n: int, lo: float,
                       hi: float) -> np.ndarray:
    """Prompt sizes (bits), uniform in ``[lo, hi)``."""
    return rng.uniform(lo, hi, n)


def sample_gen_tokens(rng: np.random.Generator, n: int, lo: int,
                      hi: int) -> np.ndarray:
    """Generation lengths (tokens), uniform integers in ``[lo, hi)``;
    ``hi <= lo`` degenerates to the constant ``lo`` (a fixed-length
    stream) without consuming a draw."""
    if hi <= lo:
        return np.full(n, lo)
    return rng.integers(lo, hi, n)


def sample_cells(rng: np.random.Generator, n: int, num_cells: int,
                 probs=None) -> np.ndarray:
    """Requesting-cell column: uniform or hotspot-skewed."""
    if probs is None:
        return rng.integers(0, num_cells, n)
    return sample_categorical(rng, n, probs)


def sample_deadlines(rng: np.random.Generator, n: int,
                     mix) -> Optional[np.ndarray]:
    """SLO deadline column: categorical draws from a
    ``((deadline_s, weight), ...)`` mix (``float("inf")`` entries carry
    no SLO). An empty/None mix returns ``None`` — no deadline column,
    and the admission check compiles out of the router entirely."""
    if not mix:
        return None
    vals = np.asarray([v for v, _ in mix], float)
    weights = [w for _, w in mix]
    return vals[sample_categorical(rng, n, weights)]


def stream_fields(rng: np.random.Generator, n: int, num_models: int, *,
                  model_probs=None, model_rows=None,
                  prompt_bits=(1e5, 1e6), gen_tokens=(8, 128),
                  num_cells: int = 1, cell_probs=None) -> dict:
    """The per-request columns drawn from ONE generator in the canonical
    order (model, prompt, gen, cell) — byte-compatible with the legacy
    sequentially-consumed streams. Returns plain arrays; ``cell`` is
    ``None`` for single-cell topologies."""
    return {
        "model": sample_models(rng, n, num_models, model_probs, model_rows),
        "prompt_bits": sample_prompt_bits(rng, n, *prompt_bits),
        "gen_tokens": sample_gen_tokens(rng, n, *gen_tokens),
        "cell": (sample_cells(rng, n, num_cells, cell_probs)
                 if num_cells > 1 else None),
    }


def to_request_batch(fields: dict, arrivals: Optional[np.ndarray]):
    """Pack generator outputs into a jit-ready ``RequestBatch``
    (struct-of-arrays, router dtypes)."""
    from repro.core.batch_router import RequestBatch

    return RequestBatch(
        model=jnp.asarray(fields["model"], jnp.int32),
        prompt_bits=jnp.asarray(fields["prompt_bits"], jnp.float32),
        gen_tokens=jnp.asarray(fields["gen_tokens"], jnp.float32),
        cell=(None if fields.get("cell") is None
              else jnp.asarray(fields["cell"], jnp.int32)),
        arrival_s=(None if arrivals is None
                   else jnp.asarray(arrivals, jnp.float32)),
        deadline_s=(None if fields.get("deadline_s") is None
                    else jnp.asarray(fields["deadline_s"], jnp.float32)),
    )
