"""Declarative scenario specs + the named-scenario registry.

A ``ScenarioSpec`` is a flat NamedTuple pytree describing one synthetic
IIoT traffic shape: the arrival process, the model-popularity
distribution (and its drift), the per-cell skew and the length
distributions. ``compile_scenario(spec, seed=..., num_models=...,
num_cells=...)`` lowers it to a ``core.batch_router.RequestBatch`` for
any fleet topology — bit-identically reproducible from ``(spec, seed)``
(each component draws from its own ``SeedSequence`` child, so e.g.
changing the arrival process never reshuffles the model column).

The registry holds the named scenarios every policy/router/benchmark is
evaluated against (``benchmarks/scenario_suite.py`` runs the full
policies x scenarios matrix; ``launch/serve.py --scenario <name>``
serves one). Each stresses a different term of the paper's cost model —
see ``docs/scenarios.md`` for the full table:

  * ``steady``            — homogeneous Poisson, static Zipf popularity
  * ``bursty``            — Markov-modulated bursts (eq. 9 queue stress)
  * ``diurnal``           — sinusoid day/night cycle
  * ``flash-crowd``       — one multiplicative arrival spike
  * ``popularity-drift``  — Zipf rank order re-drawn every drift period
    (eq. 7 switch churn — the model-switching dynamic the paper is
    about)
  * ``hotspot-cell``      — one cell absorbs most traffic (cell-mask /
    cloud-fallback stress)

plus the DEGRADED-SERVICE family (``docs/robustness.md``;
``benchmarks/degraded_suite.py`` runs it end to end):

  * ``slo-mix``           — steady traffic with a mixed-SLO deadline
    column (admission-control stress)
  * ``flash-crowd-outage``— the flash-crowd spike while one server is
    down, under SLO admission (the overload-economy acceptance case)
  * ``drain-outage``      — the spike while a server's DRAIN stalls
    (it still accepts work, its backlog just stops moving)

A spec may carry a ``FaultSpec``: ``(server, start_s, end_s)`` fault
windows that ``workloads.simulate`` lowers to per-window ``outage``
masks (full outage: ``+inf`` column + frozen queue) or drain stalls
(``drain_rate -> 0``, still routable).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

from repro.workloads import generators as gen


class FaultSpec(NamedTuple):
    """Fault-injection schedule for one scenario (flat, serialisable).

    Both fields are tuples of ``(server_index, start_s, end_s)`` windows
    against the request stream's wall clock (half-open: a window is
    active while ``start_s <= t < end_s``):

      * ``outages`` — full server outages: the column scores ``+inf``
        (never routed to; rejections report ``CAUSE_OUTAGE``) and the
        queue freezes — no drain while down.
      * ``drain_outages`` — drain stalls: the server keeps ACCEPTING
        work at its normal price but its continuous ``drain_rate``
        drops to zero, so backlog accumulates silently.
    """

    outages: tuple = ()
    drain_outages: tuple = ()


class ScenarioSpec(NamedTuple):
    """One synthetic traffic shape, declaratively.

    Only the fields of the selected ``arrival`` kind are read; the rest
    are inert defaults, which keeps the spec a flat, easily serialised
    pytree. ``prompt_bits`` is a uniform ``[lo, hi)`` range in bits;
    ``gen_tokens`` a uniform integer ``[lo, hi)`` range (``hi <= lo``:
    constant-length stream)."""

    name: str = "custom"
    num_requests: int = 1024
    # arrival process: poisson | bursts | mmpp | diurnal | flash
    arrival: str = "poisson"
    rate: float = 200.0            # req/s (the quiet rate for mmpp)
    burst: int = 64                # bursts: requests per burst
    burst_gap_s: float = 0.5       # bursts: quiet gap between bursts
    jitter_s: float = 1e-3         # bursts: spread within a burst
    rate_hi: float = 2000.0        # mmpp: burst-state rate
    dwell_lo_s: float = 2.0        # mmpp: mean quiet sojourn
    dwell_hi_s: float = 0.25       # mmpp: mean burst sojourn
    period_s: float = 5.0          # diurnal: cycle length
    depth: float = 0.9             # diurnal: modulation depth in [0, 1)
    spike_start_s: float = 3.0     # flash: spike window start
    spike_dur_s: float = 1.0       # flash: spike window length
    spike_mult: float = 20.0       # flash: intensity multiplier
    # model popularity
    zipf_s: float = 0.0            # Zipf skew (0 = uniform)
    drift_period_s: Optional[float] = None  # None = static rank order
    # per-cell skew (multi-cell topologies only)
    hotspot_cell: Optional[int] = None
    hotspot_weight: float = 0.7
    # length distributions
    prompt_bits: tuple = (1e5, 1e6)
    gen_tokens: tuple = (8, 128)
    # robustness knobs (docs/robustness.md)
    deadline_mix: tuple = ()   # ((deadline_s, weight), ...); () = no SLO
    faults: FaultSpec = FaultSpec()


def _arrivals(spec: ScenarioSpec, rng: np.random.Generator) -> np.ndarray:
    n = spec.num_requests
    if spec.arrival == "poisson":
        return gen.poisson_arrivals(rng, n, spec.rate)
    if spec.arrival == "bursts":
        return gen.burst_train_arrivals(rng, n, spec.burst, spec.burst_gap_s,
                                        spec.jitter_s)
    if spec.arrival == "mmpp":
        return gen.mmpp_arrivals(rng, n, spec.rate, spec.rate_hi,
                                 spec.dwell_lo_s, spec.dwell_hi_s)
    if spec.arrival == "diurnal":
        return gen.diurnal_arrivals(rng, n, spec.rate, spec.period_s,
                                    spec.depth)
    if spec.arrival == "flash":
        return gen.flash_crowd_arrivals(rng, n, spec.rate, spec.spike_start_s,
                                        spec.spike_dur_s, spec.spike_mult)
    raise ValueError(f"unknown arrival process {spec.arrival!r}")


def compile_scenario(spec: ScenarioSpec, *, seed: int, num_models: int,
                     num_cells: int = 1):
    """Lower a spec to a jit-ready ``RequestBatch`` (sorted arrival
    stamps included; ``cell=None`` when ``num_cells == 1``).

    Determinism: the arrival process, the drift permutations and each
    per-request column draw from independent ``SeedSequence`` children
    of ``seed``, so the same ``(spec, seed, num_models, num_cells)``
    regenerates the stream bit-identically in any process. (The
    deadline child is LAST in the spawn order, so pre-SLO scenarios
    regenerate their exact historical streams.)"""
    (rng_arr, rng_drift, rng_model, rng_prompt, rng_gen, rng_cell,
     rng_deadline) = gen.component_rngs(seed, 7)
    arrivals = _arrivals(spec, rng_arr)

    model_probs = model_rows = None
    if spec.drift_period_s is not None:
        windows = int(arrivals[-1] // spec.drift_period_s) + 1
        model_probs, _ = gen.drifting_popularity(rng_drift, windows,
                                                 num_models, spec.zipf_s)
        model_rows = np.minimum(
            (arrivals // spec.drift_period_s).astype(np.int64), windows - 1
        )
    elif spec.zipf_s:
        model_probs = gen.zipf_popularity(num_models, spec.zipf_s)

    cell_probs = None
    if num_cells > 1 and spec.hotspot_cell is not None:
        cell_probs = gen.hotspot_cell_probs(num_cells, spec.hotspot_cell,
                                            spec.hotspot_weight)

    n = spec.num_requests
    fields = {
        "model": gen.sample_models(rng_model, n, num_models, model_probs,
                                   model_rows),
        "prompt_bits": gen.sample_prompt_bits(rng_prompt, n,
                                              *spec.prompt_bits),
        "gen_tokens": gen.sample_gen_tokens(rng_gen, n, *spec.gen_tokens),
        "cell": (gen.sample_cells(rng_cell, n, num_cells, cell_probs)
                 if num_cells > 1 else None),
        "deadline_s": gen.sample_deadlines(rng_deadline, n,
                                           spec.deadline_mix),
    }
    return gen.to_request_batch(fields, arrivals)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, ScenarioSpec] = {}


def register(spec: ScenarioSpec) -> ScenarioSpec:
    """Add a named spec to the registry (last write wins)."""
    _REGISTRY[spec.name] = spec
    return spec


def get_scenario(name: str, **overrides) -> ScenarioSpec:
    """Look up a registered spec, optionally overriding fields
    (e.g. ``get_scenario("steady", num_requests=4096)``)."""
    try:
        spec = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r}; registered: {list_scenarios()}"
        ) from None
    return spec._replace(**overrides) if overrides else spec


def list_scenarios() -> list[str]:
    """Registered scenario names, registration order."""
    return list(_REGISTRY)


register(ScenarioSpec(name="steady", arrival="poisson", rate=200.0,
                      zipf_s=1.5))
register(ScenarioSpec(name="bursty", arrival="mmpp", rate=50.0,
                      rate_hi=2000.0, dwell_lo_s=2.0, dwell_hi_s=0.25,
                      zipf_s=1.5))
register(ScenarioSpec(name="diurnal", arrival="diurnal", rate=200.0,
                      period_s=5.0, depth=0.9, zipf_s=1.5))
register(ScenarioSpec(name="flash-crowd", arrival="flash", rate=100.0,
                      spike_start_s=3.0, spike_dur_s=1.0, spike_mult=20.0,
                      zipf_s=1.5))
register(ScenarioSpec(name="popularity-drift", arrival="poisson", rate=200.0,
                      zipf_s=1.5, drift_period_s=0.1))
register(ScenarioSpec(name="hotspot-cell", arrival="poisson", rate=200.0,
                      zipf_s=1.5, hotspot_cell=0, hotspot_weight=0.7))

# --- degraded-service family (docs/robustness.md) --------------------------
# Deadlines are in seconds of predicted eq. 11 latency; the mixes keep a
# no-SLO share so completion never collapses to the strictest class.
register(ScenarioSpec(name="slo-mix", arrival="poisson", rate=200.0,
                      zipf_s=1.5,
                      deadline_mix=((0.1, 0.25), (1.0, 0.5),
                                    (float("inf"), 0.25))))
# Uniform popularity (zipf 0): the heavyweight models keep their full
# token share, so the backlog term — not the uplink — dominates the
# eq. 11 score and the SLO can act as the queue's relief valve. The
# outage takes down BOTH servers of cell 0 (the whole cell), so
# rejections split honestly between CAUSE_ADMISSION and CAUSE_OUTAGE.
register(ScenarioSpec(name="flash-crowd-outage", arrival="flash", rate=100.0,
                      spike_start_s=3.0, spike_dur_s=1.0, spike_mult=20.0,
                      zipf_s=0.0,
                      deadline_mix=((0.02, 0.6), (0.25, 0.25),
                                    (float("inf"), 0.15)),
                      faults=FaultSpec(outages=((0, 3.0, 4.5),
                                                (1, 3.0, 4.5)))))
register(ScenarioSpec(name="drain-outage", arrival="flash", rate=100.0,
                      spike_start_s=3.0, spike_dur_s=1.0, spike_mult=20.0,
                      zipf_s=1.5,
                      faults=FaultSpec(drain_outages=((0, 3.0, 4.5),
                                                      (1, 3.0, 4.5)))))
