"""Long-horizon serving simulator: an arbitrarily long request stream
windowed into chunked ``route_batch`` calls.

``route_batch`` already owns the semantics (sequential commit, cell
mask, time-based drain); the simulator's job is the EPISODE: it slices
the stream into fixed-size request windows, routes each window with the
``FleetState`` carried from the previous one (LRU residency, queues,
``time_s`` — nothing resets between windows), and aggregates a
per-window time series on top of the concatenated outcome
(``core.batch_router.window_stats``) plus queue-depth percentiles
sampled at every window boundary — the only instants the queues are
observable from outside the jitted call.

Because the scan commits requests strictly in stream order, windowing
is a pure re-chunking: for a drain-free stream the W-window episode
bit-matches ONE ``route_batch`` call on the whole stream (choices,
latencies, final state — pinned by ``tests/test_workloads.py``).
Fixed-size windows also keep the jit cache small: every window shares
one compiled program (+1 for a ragged tail).

``benchmarks/scenario_suite.py`` runs this over the full policies x
scenarios matrix; ``examples/serve_edge.py`` prints one time series.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batch_router as br
from repro.core import costs


def request_energy_j(params: br.FleetParams, reqs: br.RequestBatch,
                     outcome: br.RouteOutcome, *, p_tx: float = 0.5,
                     p_bh: float = 2.0, kappa: float = 1e-29) -> np.ndarray:
    """Per-request serving energy (J), the eq. 6/8/10 analogue through
    the ``core.costs`` functions (the single home of the cost
    arithmetic): uplink transmission + model switch (when the request
    missed residency) + edge compute (``kappa * f^2 * work/f``). Zero
    for rejected requests.

    Under partial offload (``reqs.eta``) the edge side only transmits
    and computes the ``eta`` fraction, so both the eq. 6 and the eq. 10
    analogue scale with it — a committed ``beta = False`` request is
    necessarily a residency hit (refused misses price ``+inf`` and are
    never chosen), so the eq. 8 hit-gating already covers the download
    decision. The shared metric under ``benchmarks/policy_serving.py``
    and the per-window series here."""
    choice = np.asarray(outcome.choice)
    ok = choice >= 0
    ch = np.maximum(choice, 0)
    model = np.asarray(reqs.model)
    flops = np.asarray(params.flops_per_s)[ch]
    prompt = np.asarray(reqs.prompt_bits)
    work = (np.asarray(reqs.gen_tokens)
            * np.asarray(params.decode_flops_per_token)[model])
    if reqs.eta is not None:  # eq. 16 offload ratio: edge share only
        eta = np.asarray(reqs.eta)
        prompt = prompt * eta
        work = work * eta
    t_trans = costs.trans_latency(
        prompt, 1.0, np.asarray(params.uplink_bps)[ch]
    )
    t_switch = np.where(
        np.asarray(outcome.hit), 0.0,
        costs.switch_latency(np.asarray(params.size_bits)[model],
                             np.asarray(params.backhaul_bps)[ch]),
    )
    e = costs.edge_total_energy(
        costs.trans_energy(p_tx, t_trans),
        costs.switch_energy(p_bh, t_switch),
        kappa * flops**2 * (work / flops),
    )
    return np.where(ok, np.asarray(e), 0.0)


def mean_request_energy_j(params: br.FleetParams, reqs: br.RequestBatch,
                          outcome: br.RouteOutcome, **kw) -> float:
    """Mean eq. 6/8/10 serving energy over COMPLETED requests — the
    aggregate both ``benchmarks/policy_serving.py`` and
    ``benchmarks/scenario_suite.py`` record."""
    ok = np.asarray(outcome.choice) >= 0
    return float(request_energy_j(params, reqs, outcome, **kw).sum()
                 / max(ok.sum(), 1))


class SimResult(NamedTuple):
    """Per-window time series of one simulated episode (arrays of length
    W = number of windows). Latency/completion/hit/cloud come from
    ``batch_router.window_stats``; the queue percentiles are over the
    EDGE servers' outstanding tokens at each window's end (the cloud
    column, when present, is excluded — its depth only dilutes the edge
    signal). The per-cause rejection rates share the window-size
    denominator with ``completion_rate``, so the four series sum to 1
    in every window (``docs/robustness.md``)."""

    window_start_s: np.ndarray    # first arrival in the window
    window_end_s: np.ndarray      # last arrival in the window
    requests: np.ndarray          # (W,) int — window sizes
    mean_latency: np.ndarray      # completed requests only
    mean_energy_j: np.ndarray     # completed requests only (eq. 6/8/10)
    completion_rate: np.ndarray
    residency_hit_rate: np.ndarray
    cloud_fallback_rate: Optional[np.ndarray]  # None without a cloud column
    queue_p50: np.ndarray         # edge queue depth percentiles at window end
    queue_p90: np.ndarray
    queue_max: np.ndarray
    infeasible_rate: Optional[np.ndarray] = None  # no visible server
    admission_rate: Optional[np.ndarray] = None   # best score > deadline_s
    outage_rate: Optional[np.ndarray] = None      # all visible servers down


def _fault_mask(windows, n: int, t: float) -> np.ndarray:
    """(n,) bool: servers whose ``(server, start_s, end_s)`` fault
    window is active at wall clock ``t`` (half-open, ``start <= t <
    end``)."""
    mask = np.zeros(n, bool)
    for srv, start, end in windows:
        if start <= t < end:
            mask[int(srv)] = True
    return mask


def simulate(params: br.FleetParams, state: br.FleetState,
             reqs: br.RequestBatch, *, policy="greedy", actor=None,
             window_requests: int = 256, drain_tokens=None,
             chunk: Optional[int] = None, unroll: int = 8,
             backend: Optional[str] = None,
             cloud_index: Optional[int] = None,
             mesh=None, num_devices: Optional[int] = None,
             faults=None):
    """Route ``reqs`` through W sequential windows, carrying the fleet
    state across window boundaries; returns ``(state, outcome, series)``
    with ``outcome`` the concatenated ``RouteOutcome`` of the whole
    stream and ``series`` the per-window ``SimResult``.

    All ``route_batch`` knobs pass through (``policy``/``actor``,
    ``chunk``/``unroll``/``backend``, per-request ``drain_tokens``);
    ``cloud_index`` (the cloud column's server index, conventionally the
    last) adds the cloud-fallback rate to the series and excludes that
    column from the queue percentiles.

    ``mesh``/``num_devices`` switch each window to the mesh-sharded
    router (``core.mesh_router.route_batch_sharded``): a simulator
    window IS the sharded router's reconciliation window, so cells see
    each other's cloud commits at exactly the boundaries the series
    samples. Mutually exclusive with ``drain_tokens`` (a cross-cell
    sequential coupling the sharded window model cannot honour).

    ``faults`` (a ``workloads.scenario.FaultSpec``) injects server
    faults: each window is routed under the fault masks active at its
    FIRST arrival — full ``outages`` become the router's ``outage``
    mask (``+inf`` column, frozen queue, ``CAUSE_OUTAGE`` rejections),
    ``drain_outages`` zero the affected servers' ``drain_rate`` (they
    keep accepting work). Fault-free windows compile the knobs out, so
    a schedule costs at most one extra jit program."""
    sharded = mesh is not None or num_devices is not None
    if sharded:
        if drain_tokens is not None:
            raise ValueError(
                "drain_tokens couples every request to the previous one "
                "fleet-wide; the mesh-sharded windows cannot honour it — "
                "drop the mesh or use params.drain_rate time-based drain"
            )
        from repro.core import mesh_router
    n_srv = int(np.asarray(params.flops_per_s).shape[0])
    if faults is not None and (faults.outages or faults.drain_outages):
        for srv, _, _ in (*faults.outages, *faults.drain_outages):
            if not 0 <= int(srv) < n_srv:
                raise ValueError(
                    f"fault window names server {srv} but the fleet has "
                    f"{n_srv} servers"
                )
        if reqs.arrival_s is None:
            raise ValueError(
                "fault windows are scheduled against wall-clock arrival "
                "stamps; the request stream carries none (arrival_s=None)"
            )
        if faults.drain_outages and params.drain_rate is None:
            raise ValueError(
                "drain_outages stall FleetParams.drain_rate, but this "
                "fleet has no continuous drain configured"
            )
    else:
        faults = None
    b = int(reqs.model.shape[0])
    w = max(1, int(window_requests))
    n_windows = max(1, math.ceil(b / w))
    outs, q50, q90, qmax = [], [], [], []
    arr_np = (np.asarray(reqs.arrival_s)
              if reqs.arrival_s is not None else None)
    for i in range(n_windows):
        sl = slice(i * w, min((i + 1) * w, b))
        win = jax.tree.map(lambda x: x[sl], reqs)
        dw = drain_tokens
        if dw is not None and np.ndim(dw) == 1:
            dw = dw[sl]
        params_w, outage = params, None
        if faults is not None:
            t = float(arr_np[sl.start])  # the window's first arrival
            om = _fault_mask(faults.outages, n_srv, t)
            if om.any():
                outage = jnp.asarray(om)
            dm = _fault_mask(faults.drain_outages, n_srv, t)
            if dm.any():  # stalled drain: still routable, backlog grows
                params_w = params._replace(drain_rate=jnp.where(
                    jnp.asarray(dm), 0.0, params.drain_rate))
        if sharded:
            state, out = mesh_router.route_batch_sharded(
                params_w, state, win, mesh=mesh, num_devices=num_devices,
                policy=policy, actor=actor, chunk=chunk, unroll=unroll,
                backend=backend, outage=outage)
        else:
            state, out = br.route_batch(params_w, state, win, dw,
                                        policy=policy, actor=actor,
                                        chunk=chunk, unroll=unroll,
                                        backend=backend, outage=outage)
        outs.append(out)
        q = np.asarray(state.queue_tokens)
        if cloud_index is not None:
            q = np.delete(q, cloud_index)
        q50.append(np.percentile(q, 50))
        q90.append(np.percentile(q, 90))
        qmax.append(q.max())

    outcome = br.RouteOutcome(
        *(jnp.concatenate([getattr(o, f) for o in outs])
          for f in br.RouteOutcome._fields)
    )
    window_id = np.arange(b) // w
    stats = br.window_stats(
        outcome, window_id, n_windows, cloud_index=cloud_index,
        completed_means={
            "mean_energy_j": request_energy_j(params, reqs, outcome)
        },
    )
    if reqs.arrival_s is not None:
        arr = np.asarray(reqs.arrival_s)
    else:  # no wall clock: use request indices as the time axis
        arr = np.arange(b, dtype=float)
    t0 = np.minimum.reduceat(arr, np.arange(0, b, w))
    t1 = np.maximum.reduceat(arr, np.arange(0, b, w))
    series = SimResult(
        window_start_s=t0, window_end_s=t1,
        requests=stats["requests"],
        mean_latency=stats["mean_latency"],
        mean_energy_j=stats["mean_energy_j"],
        completion_rate=stats["completion_rate"],
        residency_hit_rate=stats["residency_hit_rate"],
        cloud_fallback_rate=stats.get("cloud_fallback_rate"),
        queue_p50=np.asarray(q50), queue_p90=np.asarray(q90),
        queue_max=np.asarray(qmax),
        infeasible_rate=stats.get("infeasible_rate"),
        admission_rate=stats.get("admission_rate"),
        outage_rate=stats.get("outage_rate"),
    )
    return state, outcome, series
