"""Scenario workload subsystem: generated IIoT traffic + long-horizon
serving simulation.

Three modules, one pipeline:

  * :mod:`repro.workloads.generators` — composable, RNG-keyed traffic
    primitives (arrival processes, popularity distributions, per-cell
    skew, length distributions). Every stream regenerates bit-identically
    from ``(spec, seed)``.
  * :mod:`repro.workloads.scenario` — the declarative ``ScenarioSpec``
    pytree plus the registry of named scenarios (``steady``, ``bursty``,
    ``diurnal``, ``flash-crowd``, ``popularity-drift``,
    ``hotspot-cell``, and the degraded-service family ``slo-mix`` /
    ``flash-crowd-outage`` / ``drain-outage``); ``compile_scenario``
    turns a spec into a ``core.batch_router.RequestBatch`` for any
    fleet topology, and a ``FaultSpec`` schedules server outages /
    drain stalls against the stream's wall clock.
  * :mod:`repro.workloads.simulate` — the long-horizon episode runner:
    windows an arbitrarily long stream into chunked ``route_batch``
    calls, carries ``FleetState`` across windows and aggregates
    per-window time series.

``launch/serve.py --scenario <name>`` and
``benchmarks/scenario_suite.py`` (the policies x scenarios matrix)
drive it end to end; ``docs/scenarios.md`` is the guide.
"""
from repro.workloads.scenario import (  # noqa: F401
    FaultSpec,
    ScenarioSpec,
    compile_scenario,
    get_scenario,
    list_scenarios,
    register,
)
from repro.workloads.simulate import SimResult, simulate  # noqa: F401
