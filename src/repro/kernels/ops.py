"""Jit'd dispatch layer: every hot-spot op routes to either the Pallas TPU
kernel (``backend="pallas"``, validated in interpret mode on CPU) or the
memory-sane XLA implementation (``backend="xla"``, used by the CPU
dry-run — Pallas TPU kernels cannot lower on the host platform).

Both backends share the oracles in ``ref.py``; tests assert allclose.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ref

_INTERPRET = True  # this container is CPU-only; on TPU set False


def rmsnorm(x, scale, *, eps: float = 1e-6, backend: str = "xla"):
    if backend == "pallas":
        from repro.kernels import rmsnorm as _k

        return _k.rmsnorm(x, scale, eps=eps, interpret=_INTERPRET)
    return ref.rmsnorm_naive(x, scale, eps)


def attention(q, k, v, *, causal=True, window=0, q_offset=0, backend: str = "xla"):
    if backend == "pallas":
        from repro.kernels import flash_attention as _k

        return _k.flash_attention(
            q, k, v, causal, window, q_offset, 128, 128, _INTERPRET
        )
    return ref.attention_xla(q, k, v, causal=causal, window=window, q_offset=q_offset)


def decode_attention(q, k, v, pos, *, window=0, backend: str = "xla"):
    if backend == "pallas":
        from repro.kernels import flash_decode as _k

        return _k.flash_decode(q, k, v, pos, window=window, interpret=_INTERPRET)
    return ref.decode_attention_naive(q, k, v, pos, window=window)


def ssd(x, dt, a_log, b, c, d_skip, *, chunk: int = 256, backend: str = "xla"):
    if backend == "pallas":
        from repro.kernels import ssd_scan as _k

        return _k.ssd(x, dt, a_log, b, c, d_skip, chunk, _INTERPRET)
    return ref.ssd_chunked_xla(x, dt, a_log, b, c, d_skip, chunk=chunk)


def ssd_decode(state, xt, dtt, a_log, bt, ct, d_skip, *, backend: str = "xla"):
    # single recurrent step is bandwidth-trivial; always the jnp path
    del backend
    return ref.ssd_decode_naive(state, xt, dtt, a_log, bt, ct, d_skip)


def route_score(
    prompt_bits, size_bits, flops_tok, work,
    uplink_bps, backhaul_bps, flops_per_s,
    queue_tokens=None, resident=None, model=None,
    req_cell=None, srv_cell=None, spill=None, eta=None, beta=None,
    *, cloud_cell: int = -1, backend: str = "xla",
):
    """Fused (B, N) eq. 11 routing-score matrix (see ``route_score.py``).

    Backends: ``"xla"`` (reference contraction), ``"pallas"`` (TPU
    kernel; interpreted when this host is CPU-only), and
    ``"pallas-interpret"`` (force interpret mode — the value the
    ``REPRO_ROUTER_BACKEND`` env knob uses on CPU CI). ``eta``/``beta``
    are the eq. 16 partial-offload / download-refusal columns; both
    backends fold them through ``costs.apply_eta_beta`` so the
    transform (and its ``None`` bitwise no-op) is shared.
    """
    if backend in ("pallas", "pallas-interpret"):
        from repro.kernels import route_score as _k

        return _k.route_score(
            prompt_bits, size_bits, flops_tok, work,
            uplink_bps, backhaul_bps, flops_per_s,
            queue_tokens=queue_tokens, resident=resident, model=model,
            req_cell=req_cell, srv_cell=srv_cell, spill=spill,
            eta=eta, beta=beta, cloud_cell=cloud_cell,
            interpret=_INTERPRET or backend == "pallas-interpret",
        )
    return ref.route_score_xla(
        prompt_bits, size_bits, flops_tok, work,
        uplink_bps, backhaul_bps, flops_per_s,
        queue_tokens=queue_tokens, resident=resident, model=model,
        req_cell=req_cell, srv_cell=srv_cell, spill=spill,
        eta=eta, beta=beta, cloud_cell=cloud_cell,
    )
