"""Jit'd dispatch layer: every hot-spot op routes to either the Pallas TPU
kernel (``backend="pallas"``, validated in interpret mode on CPU) or the
memory-sane XLA implementation (``backend="xla"``, used by the CPU
dry-run — Pallas TPU kernels cannot lower on the host platform).

Both backends share the oracles in ``ref.py``; tests assert allclose.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ref

_INTERPRET = True  # this container is CPU-only; on TPU set False


def rmsnorm(x, scale, *, eps: float = 1e-6, backend: str = "xla"):
    if backend == "pallas":
        from repro.kernels import rmsnorm as _k

        return _k.rmsnorm(x, scale, eps=eps, interpret=_INTERPRET)
    return ref.rmsnorm_naive(x, scale, eps)


def attention(q, k, v, *, causal=True, window=0, q_offset=0, backend: str = "xla"):
    if backend == "pallas":
        from repro.kernels import flash_attention as _k

        return _k.flash_attention(
            q, k, v, causal, window, q_offset, 128, 128, _INTERPRET
        )
    return ref.attention_xla(q, k, v, causal=causal, window=window, q_offset=q_offset)


def decode_attention(q, k, v, pos, *, window=0, backend: str = "xla"):
    if backend == "pallas":
        from repro.kernels import flash_decode as _k

        return _k.flash_decode(q, k, v, pos, window=window, interpret=_INTERPRET)
    return ref.decode_attention_naive(q, k, v, pos, window=window)


def ssd(x, dt, a_log, b, c, d_skip, *, chunk: int = 256, backend: str = "xla"):
    if backend == "pallas":
        from repro.kernels import ssd_scan as _k

        return _k.ssd(x, dt, a_log, b, c, d_skip, chunk, _INTERPRET)
    return ref.ssd_chunked_xla(x, dt, a_log, b, c, d_skip, chunk=chunk)


def ssd_decode(state, xt, dtt, a_log, bt, ct, d_skip, *, backend: str = "xla"):
    # single recurrent step is bandwidth-trivial; always the jnp path
    del backend
    return ref.ssd_decode_naive(state, xt, dtt, a_log, bt, ct, d_skip)
