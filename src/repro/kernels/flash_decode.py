"""Flash-decode: single-query attention over a long KV cache (Pallas TPU).

The serve-path hot spot (decode_32k / long_500k cells): one query per
sequence attends over S cached keys. The kernel blocks the KV sequence
through VMEM with the online-softmax state in scratch — the query block
stays resident. Masking handles both the causal bound (``pos``) and
sliding windows. GQA: all q heads of one kv group ride in one block, so
the K/V panel is loaded once per group (the bandwidth-optimal layout —
this kernel is HBM-bound by the KV stream).

Grid: (B * KV_heads, num_k_blocks) — k innermost, sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, window, block_k, num_k_blocks):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (rep, d) — the q heads of this kv group
    k = k_ref[0].astype(jnp.float32)  # (bk, d)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (rep, bk)

    pos = pos_ref[0]
    kj = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kj <= pos
    if window > 0:
        mask &= kj > pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_cur

    @pl.when(kb == num_k_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_decode(q, k, v, pos, *, window=0, block_k=512, interpret=False):
    """q: (B, 1, H, D); k, v: (B, S, KV, D); pos: scalar int32."""
    b, _, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    rep = h // kv
    block_k = min(block_k, s)
    assert s % block_k == 0
    nk = s // block_k

    qt = q[:, 0].reshape(b, kv, rep, d).reshape(b * kv, rep, d)
    kt = k.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    vt = v.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    pos_arr = jnp.full((1,), pos, jnp.int32)

    kernel = functools.partial(
        _kernel, scale=1.0 / (d**0.5), window=window, block_k=block_k,
        num_k_blocks=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * kv, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, rep, d), lambda g, kb: (g, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, kb: (g, kb, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, kb: (g, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, rep, d), lambda g, kb: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv, rep, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, d), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, qt, kt, vt)
    return out.reshape(b, kv, rep, d).reshape(b, 1, h, d)
