"""Fused RMSNorm Pallas kernel: one HBM round-trip per row block.

Unfused XLA does mean-square reduce + rsqrt + scale as separate HBM
passes at worst; the kernel streams (rows x d) panels through VMEM once.
fp32 math, input dtype preserved.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * s_ref[...].astype(jnp.float32)
                  ).astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 128,
            interpret: bool = False):
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    if rows % block_rows:
        block_rows = 1  # ragged fallback
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(shape)
