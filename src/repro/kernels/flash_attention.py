"""Flash attention (causal, GQA, sliding-window) as a Pallas TPU kernel.

TPU adaptation of the GPU flash-attention idea: instead of warp-level
softmax reductions, the kernel tiles (q_block x k_block) score panels
through VMEM with the running-max/running-sum online softmax held in VMEM
scratch that persists across the sequential k grid dimension (TPU grids
execute minor-most-first, sequentially per core). Matmul panels are MXU-
shaped (128x128 default) and accumulation is fp32 regardless of input
dtype.

Grid: (B*H, num_q_blocks, num_k_blocks) — k innermost.
Backward: custom_vjp whose bwd is the VJP of the numerically identical
XLA reference (fwd kernel serves inference + fwd-pass; a dedicated bwd
kernel is a further optimization documented in EXPERIMENTS §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale,
            causal, window, q_offset, block_q, block_k, num_k_blocks, rep):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (bq, d)
    k = k_ref[0].astype(jnp.float32)  # (bk, d)
    v = v_ref[0].astype(jnp.float32)  # (bk, d)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    qb = pl.program_id(1)
    qi = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + q_offset
    kj = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= kj <= qi
    if window > 0:
        mask &= kj > qi - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]  # (bq, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_cur

    @pl.when(kb == num_k_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _flash_fwd(q, k, v, *, causal, window, q_offset, block_q, block_k, interpret):
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    rep = h // kv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    nq, nk = sq // block_q, sk // block_k

    # layout: (B, H, S, D) blocks over (bh, qb, kb)
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kt = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, d)
    vt = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, d)

    kernel = functools.partial(
        _kernel,
        scale=1.0 / (d**0.5),
        causal=causal,
        window=window,
        q_offset=q_offset,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=nk,
        rep=rep,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qb, kb: (bh, qb, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qb, kb, rep=rep: (bh // rep, kb, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qb, kb, rep=rep: (bh // rep, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qb, kb: (bh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8)
)
def flash_attention(q, k, v, causal=True, window=0, q_offset=0, block_q=128,
                    block_k=128, interpret=False):
    return _flash_fwd(q, k, v, causal=causal, window=window, q_offset=q_offset,
                      block_q=block_q, block_k=block_k, interpret=interpret)


def _fwd(q, k, v, causal, window, q_offset, block_q, block_k, interpret):
    out = _flash_fwd(q, k, v, causal=causal, window=window, q_offset=q_offset,
                     block_q=block_q, block_k=block_k, interpret=interpret)
    return out, (q, k, v)


def _bwd(causal, window, q_offset, block_q, block_k, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention_xla(
            q_, k_, v_, causal=causal, window=window, q_offset=q_offset
        ),
        q, k, v,
    )
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
