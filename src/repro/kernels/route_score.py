"""Fused (B, N) routing-score matrix as a Pallas TPU kernel.

The paper's eq. 11 offloading decision prices every request x server
pair with three terms — uplink transmission (eq. 5), a model-switch
download gated on residency (eq. 7), and FIFO compute against the queue
backlog (eq. 9). ``core.batch_router.score_matrix`` evaluates the full
(B, N) contraction; this kernel computes it in ONE VMEM pass, tiled over
(block_b, block_n) panels:

  * per-request columns ride in as a packed (8, B) feature strip and
    per-server columns as an (8, N) strip, so each tile reads two thin
    slabs instead of B x N scalars;
  * the residency gate ``resident[n, model_b]`` is an MXU contraction:
    one-hot(model) (B, K) @ resident.T (K, N) — the same score-panel
    trick the flash-attention kernel uses for its mask, so no (B, N)
    gather ever materialises in HBM;
  * the multi-cell visibility mask (in-cell servers + the fleet-wide
    ``cloud_cell`` column scoring everything else ``+inf``) is fused
    into the same pass.

Non-multiple (B, N, K) shapes are zero/one-padded up to the tile grid
and sliced back; padded lanes never reach the caller. Math runs in fp32
for fp32/bf16 inputs (output cast back) and in fp64 for fp64 inputs —
the x64 oracle-equivalence tier runs the kernel too, and interpret mode
(the only place fp64 occurs) supports it. ``interpret=True`` runs the
kernel on CPU per the ``kernels/ops.py`` convention; the XLA reference
lives in ``kernels/ref.route_score_xla`` (same arithmetic via
``core.costs.edge_score_matrix``) and the two are pinned allclose in
``tests/test_route_score_kernel.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _kernel(*refs, has_switch, has_resident, has_cells, has_spill,
            cloud_cell, out_dtype):
    refs = list(refs)
    req = refs.pop(0)[...]  # (8, bb) request strip (compute dtype)
    srv = refs.pop(0)[...]  # (8, bn) server strip
    prompt = req[0][:, None]
    size = req[1][:, None]
    flops_tok = req[2][:, None]
    work = req[3][:, None]
    uplink = srv[0][None, :]
    backhaul = srv[1][None, :]
    flops = srv[2][None, :]
    queue = srv[3][None, :]

    t_trans = prompt / uplink                      # eq. 5
    t_comp = (queue * flops_tok + work) / flops    # eq. 9
    if has_switch:
        t_switch = size / backhaul                 # eq. 7 (ungated price)
        if has_resident:
            onehot = refs.pop(0)[...]              # (bb, Kp)
            resident_t = refs.pop(0)[...]          # (Kp, bn)
            res = jax.lax.dot_general(             # resident[n, model_b]
                onehot, resident_t, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ) > 0.5
            t_switch = jnp.where(res, 0.0, t_switch)  # residency gate
        score = t_trans + t_switch + t_comp        # eq. 11
    else:
        score = t_trans + t_comp                   # switch-free base

    if has_cells:
        req_cell = refs.pop(0)[...]                # (1, bb) int32
        srv_cell = refs.pop(0)[...]                # (1, bn) int32
        home = srv_cell[0][None, :] == req_cell[0][:, None]
        visible = home | (srv_cell[0][None, :] == cloud_cell)
        if has_spill:
            # neighbour-cell spill: the adjacency row is gathered by the
            # same MXU trick as the residency gate — one-hot(req_cell)
            # (bb, Cp) @ adjacency columns (Cp, bn); OOB request cells
            # have all-zero one-hot rows, so orphans never spill
            oh_cell = refs.pop(0)[...]             # (bb, Cp)
            adj_srv = refs.pop(0)[...]             # (Cp, bn)
            spilled = jax.lax.dot_general(
                oh_cell, adj_srv, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ) > 0.5
            spilled = spilled & ~home
            # backhaul surcharge: the prompt crosses the inter-cell link
            score = score + jnp.where(spilled, prompt / backhaul, 0.0)
            visible = visible | spilled
        score = jnp.where(visible, score, jnp.inf)
    refs[0][...] = score.astype(out_dtype)


def _pack_rows(rows, width, pad_values, dtype):
    """(8, width) strip: each row right-padded with its pad value."""
    strip = jnp.zeros((8, width), dtype)
    for i, (row, fill) in enumerate(zip(rows, pad_values)):
        strip = strip.at[i, : row.shape[0]].set(row.astype(dtype))
        if fill != 0.0:
            strip = strip.at[i, row.shape[0]:].set(fill)
    return strip


def route_score(
    prompt_bits, size_bits, flops_tok, work,
    uplink_bps, backhaul_bps, flops_per_s,
    queue_tokens=None, resident=None, model=None,
    req_cell=None, srv_cell=None, spill=None, eta=None, beta=None,
    *, cloud_cell: int = -1, block_b: int = 128, block_n: int = 128,
    interpret: bool = False, out_dtype=None,
):
    """Fused eq. 11 cost matrix, (B,) request x (N,) server columns.

    ``resident`` (N, K) + ``model`` (B,) enable the residency gate
    (``None`` prices every pair at the full switch cost);
    ``size_bits=None`` drops the eq. 7 term entirely and
    ``queue_tokens=None`` the backlog term — the chunked router's
    switch-free base. ``req_cell``/``srv_cell`` fuse the block-diagonal
    visibility mask (out-of-cell pairs score ``+inf``); ``spill`` (a
    (C, C) bool adjacency) widens it with backhaul-priced neighbour-cell
    pairs (surcharge ``prompt_bits / backhaul_bps``).

    ``eta`` (B,) scales prompt and work before the strips are packed —
    ``(x * eta) / r`` is the IEEE grouping of eq. 5/9's ``x eta / r``,
    so the kernel body needs no eta lane and ``eta=None`` is bitwise
    today's path. ``beta`` (B,) False poisons ``size_bits`` to ``+inf``:
    the in-kernel residency gate (a select, never a multiply) still
    zeroes hits, and every refused miss prices ``+inf``.
    """
    from repro.core import costs  # leaf module (jnp-only): no cycle

    prompt_bits, size_bits, work = costs.apply_eta_beta(
        prompt_bits, size_bits, work, eta, beta
    )
    has_switch = size_bits is not None
    has_resident = has_switch and resident is not None
    has_cells = req_cell is not None and srv_cell is not None
    has_spill = has_cells and spill is not None
    if has_resident and model is None:
        raise ValueError("resident gating requires the request model ids")
    b, n = prompt_bits.shape[0], uplink_bps.shape[0]
    if out_dtype is None:
        out_dtype = jnp.result_type(prompt_bits, uplink_bps)
    # fp32 math for fp32/bf16 inputs; fp64 only for the x64 oracle tier
    compute_dtype = jnp.promote_types(out_dtype, jnp.float32)
    bp, np_ = _round_up(b, block_b), _round_up(n, block_n)

    # divisor columns pad with 1.0 so padded lanes stay finite garbage
    # (they are sliced away below, but NaNs trip interpret-mode checks)
    zero_s = jnp.zeros((b,), compute_dtype)
    req = _pack_rows(
        [prompt_bits, zero_s if size_bits is None else size_bits,
         flops_tok, work],
        bp, [0.0, 0.0, 0.0, 0.0], compute_dtype,
    )
    zero_q = jnp.zeros((n,), compute_dtype)
    srv = _pack_rows(
        [uplink_bps, backhaul_bps, flops_per_s,
         zero_q if queue_tokens is None else queue_tokens],
        np_, [1.0, 1.0, 1.0, 0.0], compute_dtype,
    )

    grid = (bp // block_b, np_ // block_n)
    in_specs = [
        pl.BlockSpec((8, block_b), lambda i, j: (0, i)),
        pl.BlockSpec((8, block_n), lambda i, j: (0, j)),
    ]
    inputs = [req, srv]
    if has_resident:
        kp = _round_up(resident.shape[1], 128)
        onehot = jax.nn.one_hot(model, kp, dtype=jnp.float32)  # (b, kp)
        onehot = jnp.pad(onehot, ((0, bp - b), (0, 0)))
        resident_t = jnp.pad(
            resident.T.astype(jnp.float32),
            ((0, kp - resident.shape[1]), (0, np_ - n)),
        )
        in_specs += [
            pl.BlockSpec((block_b, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((kp, block_n), lambda i, j: (0, j)),
        ]
        inputs += [onehot, resident_t]
    if has_cells:
        rc = jnp.pad(req_cell.astype(jnp.int32), (0, bp - b))[None, :]
        sc = jnp.pad(srv_cell.astype(jnp.int32), (0, np_ - n))[None, :]
        in_specs += [
            pl.BlockSpec((1, block_b), lambda i, j: (0, i)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ]
        inputs += [rc, sc]
    if has_spill:
        ncell = spill.shape[0]
        cp = _round_up(ncell, 128)
        # one_hot maps OOB cells (orphans, CLOUD_CELL) to all-zero rows
        oh_cell = jax.nn.one_hot(req_cell.astype(jnp.int32), cp,
                                 dtype=jnp.float32)
        oh_cell = jnp.pad(oh_cell, ((0, bp - b), (0, 0)))
        sc_i = srv_cell.astype(jnp.int32)
        in_range = (sc_i >= 0) & (sc_i < ncell)
        adj_srv = spill.astype(jnp.float32)[:, jnp.clip(sc_i, 0, ncell - 1)]
        adj_srv = adj_srv * in_range[None, :].astype(jnp.float32)
        adj_srv = jnp.pad(adj_srv, ((0, cp - ncell), (0, np_ - n)))
        in_specs += [
            pl.BlockSpec((block_b, cp), lambda i, j: (i, 0)),
            pl.BlockSpec((cp, block_n), lambda i, j: (0, j)),
        ]
        inputs += [oh_cell, adj_srv]

    out = pl.pallas_call(
        functools.partial(
            _kernel, has_switch=has_switch, has_resident=has_resident,
            has_cells=has_cells, has_spill=has_spill,
            cloud_cell=cloud_cell, out_dtype=out_dtype,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), out_dtype),
        interpret=interpret,
    )(*inputs)
    return out[:b, :n]
