"""Pure-jnp oracles for every kernel, plus memory-sane XLA fallbacks.

Two tiers:
  * ``*_naive`` — the mathematical definition, O(S^2)/recurrent, used as
    the allclose oracle for both the Pallas kernels and the XLA paths.
  * ``*_xla``  — chunked/flash-style jnp implementations that are safe to
    compile at production shapes (no (B,H,S,S) materialisation). These are
    what the dry-run lowers when ``kernel_backend="xla"``.

Activation layout everywhere: (batch, seq, heads, head_dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# =============================== RMSNorm ======================================
def rmsnorm_naive(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(jnp.square(x32), axis=-1, keepdims=True) + eps)
    return ((x32 / rms) * scale.astype(jnp.float32)).astype(dt)


# =============================== Attention ====================================
def _gqa_expand(k, num_q_heads):
    """(B, S, KV, D) -> (B, S, H, D) by repeating kv heads."""
    b, s, kv, d = k.shape
    rep = num_q_heads // kv
    return jnp.repeat(k, rep, axis=2)


def attention_naive(q, k, v, *, causal=True, window=0, q_offset=0):
    """Oracle. q: (B, Sq, H, D); k,v: (B, Sk, KV, D). fp32 math.

    ``q_offset``: absolute position of q[0] (decode: Sk-1 for single token).
    ``window`` > 0: key j visible to query i iff i - window < j <= i.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    k = _gqa_expand(k, h)
    v = _gqa_expand(v, h)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    scores = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    qi = jnp.arange(sq)[:, None] + q_offset
    kj = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kj <= qi
    if window > 0:
        mask &= kj > qi - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_xla(q, k, v, *, causal=True, window=0, q_offset=0, q_chunk=512):
    """Flash-style: scan over query chunks; scores never exceed
    (B, H, q_chunk, Sk). fp32 accumulation, bf16-safe."""
    b, sq, h, d = q.shape
    if sq <= q_chunk:
        return attention_naive(q, k, v, causal=causal, window=window, q_offset=q_offset)
    assert sq % q_chunk == 0, (sq, q_chunk)
    nq = sq // q_chunk
    qs = q.reshape(b, nq, q_chunk, h, d)

    @jax.checkpoint  # recompute chunk scores in bwd: peak is ONE chunk's scores
    def one(carry, inp):
        qc, idx = inp
        out = attention_naive(
            qc, k, v, causal=causal, window=window, q_offset=q_offset + idx * q_chunk
        )
        return carry, out

    _, outs = jax.lax.scan(one, None, (jnp.moveaxis(qs, 1, 0), jnp.arange(nq)))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, d)


def decode_attention_naive(q, k, v, pos, *, window=0):
    """Single-token decode. q: (B, 1, H, D); k,v: (B, S, KV, D); ``pos``
    scalar absolute position of the query. Visible keys: j <= pos (and
    window if set). fp32 math; scores are (B, KV, rep, S) — always small.

    Grouped-GQA form (q reshaped to (B, KV, rep, D)) rather than repeating
    K/V to H heads: no broadcast of the cache, so under SPMD the
    S-sharded KV cache never gets resharded to head sharding (the repeat
    triggered involuntary full rematerialisation in GSPMD)."""
    b, _, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    rep = h // kv
    qg = q[:, 0].reshape(b, kv, rep, d)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    # bf16 operands + fp32 accumulation via preferred_element_type: an
    # explicit astype(f32) of K/V gets loop-hoisted by XLA into an fp32
    # mirror of the ENTIRE stacked cache (7.9 GiB/dev on llama decode).
    scores = jnp.einsum(
        "bgrd,bkgd->bgrk", qg, k, preferred_element_type=jnp.float32
    ) * scale
    kj = jnp.arange(s)
    mask = kj <= pos
    if window > 0:
        mask &= kj > pos - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bgrk,bkgd->bgrd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, h, d).astype(q.dtype)


# =============================== Mamba2 SSD ===================================
def ssd_naive(x, dt, a_log, b, c, d_skip):
    """Recurrent oracle (sequential over S, fp32).

    x: (B, S, H, P)  dt: (B, S, H)  a_log: (H,)
    b, c: (B, S, N)  d_skip: (H,)   returns (y, final_state)
    state: (B, H, P, N)
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    x32, dt32 = x.astype(jnp.float32), dt.astype(jnp.float32)
    b32, c32 = b.astype(jnp.float32), c.astype(jnp.float32)
    a = -jnp.exp(a_log.astype(jnp.float32))  # (H,) negative decay rates

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(a[None] * dtt)  # (B, H)
        add = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], bt)
        state = state * decay[..., None, None] + add
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (
        jnp.moveaxis(x32, 1, 0),
        jnp.moveaxis(dt32, 1, 0),
        jnp.moveaxis(b32, 1, 0),
        jnp.moveaxis(c32, 1, 0),
    )
    state, ys = jax.lax.scan(step, init, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B, S, H, P)
    y = y + x32 * d_skip.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), state


def ssd_chunked_xla(x, dt, a_log, b, c, d_skip, chunk: int = 256):
    """SSD chunked/blocked algorithm (Mamba2 paper §6) — scan over chunks,
    quadratic only within a chunk. Memory per step: (B, H, Q, Q)."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    s_orig = s
    if s % chunk != 0:
        # pad with dt=0 steps: exp(a*0)=1 and x*dt=0, so the state and the
        # unpadded outputs are unaffected
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // chunk
    f32 = jnp.float32
    x32 = x.astype(f32).reshape(bsz, nc, chunk, h, p)
    dt32 = dt.astype(f32).reshape(bsz, nc, chunk, h)
    b32 = b.astype(f32).reshape(bsz, nc, chunk, n)
    c32 = c.astype(f32).reshape(bsz, nc, chunk, n)
    a = -jnp.exp(a_log.astype(f32))  # (H,)

    def per_chunk(state, inp):
        xc, dtc, bc, cc = inp  # (B,Q,H,P), (B,Q,H), (B,Q,N), (B,Q,N)
        adt = a[None, None] * dtc  # (B, Q, H)
        cum = jnp.cumsum(adt, axis=1)  # (B, Q, H) log-decay from chunk start
        total = cum[:, -1]  # (B, H)

        # intra-chunk (quadratic): L[i,j] = exp(cum_i - cum_j) for j <= i
        li = cum[:, :, None, :] - cum[:, None, :, :]  # (B, Q, Q, H)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay_mat = jnp.where(causal[None, :, :, None], jnp.exp(li), 0.0)
        scores = jnp.einsum("bin,bjn->bij", cc, bc)  # (B, Q, Q)
        gate = scores[..., None] * decay_mat  # (B, Q, Q, H)
        xdt = xc * dtc[..., None]  # (B, Q, H, P)
        y_intra = jnp.einsum("bijh,bjhp->bihp", gate, xdt)

        # inter-chunk: contribution of carried state
        q_decay = jnp.exp(cum)  # (B, Q, H)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", cc, state, q_decay)

        # state update: state' = exp(total) * state + sum_j exp(total-cum_j) B_j x_j
        rem = jnp.exp(total[:, None] - cum)  # (B, Q, H)
        add = jnp.einsum("bjn,bjhp,bjh->bhpn", bc, xdt, rem)
        state = state * jnp.exp(total)[..., None, None] + add
        return state, y_intra + y_inter

    init = jnp.zeros((bsz, h, p, n), f32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (x32, dt32, b32, c32))
    state, ys = jax.lax.scan(per_chunk, init, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p)[:, :s_orig]
    y = y + x.astype(f32)[:, :s_orig] * d_skip.astype(f32)[None, None, :, None]
    return y.astype(x.dtype), state


def ssd_decode_naive(state, xt, dtt, a_log, bt, ct, d_skip):
    """One recurrent step. state: (B,H,P,N); xt: (B,H,P); dtt: (B,H);
    bt, ct: (B,N). Returns (y (B,H,P), new_state)."""
    f32 = jnp.float32
    a = -jnp.exp(a_log.astype(f32))
    decay = jnp.exp(a[None] * dtt.astype(f32))
    add = jnp.einsum("bhp,bn->bhpn", xt.astype(f32) * dtt.astype(f32)[..., None],
                     bt.astype(f32))
    new_state = state * decay[..., None, None] + add
    y = jnp.einsum("bhpn,bn->bhp", new_state, ct.astype(f32))
    y = y + xt.astype(f32) * d_skip.astype(f32)[None, :, None]
    return y.astype(xt.dtype), new_state


# ======================= Routing score (paper eq. 11) =========================
def route_score_xla(
    prompt_bits, size_bits, flops_tok, work,
    uplink_bps, backhaul_bps, flops_per_s,
    queue_tokens=None, resident=None, model=None,
    req_cell=None, srv_cell=None, cloud_cell=-1, spill=None,
    eta=None, beta=None,
):
    """XLA oracle for the fused (B, N) routing-score kernel.

    Same plain-array signature as ``route_score.route_score``; the
    eq. 5 + 7 + 9 arithmetic itself lives in
    ``core.costs.edge_score_matrix`` (the single home of the cost
    model), with the residency gather and the multi-cell visibility
    mask applied here. Out-of-cell, non-cloud pairs score ``+inf``.

    ``spill`` — an optional (C, C) bool neighbour-cell adjacency — adds
    spilled pairs (adjacent cell, not home, not cloud) to the visible
    set and prices them with the backhaul surcharge
    ``prompt_bits / backhaul_bps`` (the prompt crosses the inter-cell
    backhaul on top of the uplink — the same generalisation the cloud
    column folds into its effective uplink).

    ``eta`` (B,) scales the transmitted prompt and offloaded work (the
    eq. 16 offload ratio — spilled pairs pay the surcharge on the
    scaled prompt too); ``beta`` (B,) False refuses the eq. 7 download,
    poisoning every non-resident pair to ``+inf``. Both transforms
    happen once at entry via ``costs.apply_eta_beta`` so the kernel
    wrapper and this reference stay bit-identical.
    """
    from repro.core import costs  # leaf module (jnp-only): no cycle

    prompt_bits, size_bits, work = costs.apply_eta_beta(
        prompt_bits, size_bits, work, eta, beta
    )
    res_bn = resident[:, model].T if resident is not None else None
    score = costs.edge_score_matrix(
        prompt_bits, size_bits, flops_tok, work,
        uplink_bps, backhaul_bps, flops_per_s,
        queue_tokens=queue_tokens, resident=res_bn,
    )
    if req_cell is not None and srv_cell is not None:
        home = srv_cell[None, :] == req_cell[:, None]
        visible = home | (srv_cell[None, :] == cloud_cell)
        if spill is not None:
            nc = spill.shape[0]
            rok = (req_cell >= 0) & (req_cell < nc)
            sok = (srv_cell >= 0) & (srv_cell < nc)
            adj = spill[jnp.clip(req_cell, 0, nc - 1)][
                :, jnp.clip(srv_cell, 0, nc - 1)
            ]
            spilled = adj & rok[:, None] & sok[None, :] & ~home
            score = score + jnp.where(
                spilled, prompt_bits[:, None] / backhaul_bps[None, :], 0.0
            )
            visible = visible | spilled
        score = jnp.where(visible, score, jnp.inf)
    return score
