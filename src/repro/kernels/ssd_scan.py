"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

TPU adaptation of the CUDA SSD kernel (arXiv:2405.21060 §8): the GPU
version leans on warp-level scans; here the inter-chunk recurrence is
carried in VMEM scratch across the sequential chunk grid dimension, and
the intra-chunk quadratic part is MXU panels ((Q x Q) score matmuls).
fp32 state and accumulation throughout; inputs may be bf16.

Grid: (B * H, num_chunks) — chunks innermost (sequential recurrence).
Backward: custom_vjp via the XLA chunked reference (same numerics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref


def _kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, dskip_ref, y_ref, state_ref,
            state_scr, *, chunk, num_chunks):
    cb = pl.program_id(1)

    @pl.when(cb == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)    # (Q,)
    bm = b_ref[0].astype(jnp.float32)           # (Q, N)
    cm = c_ref[0].astype(jnp.float32)           # (Q, N)
    a = -jnp.exp(alog_ref[0].astype(jnp.float32))  # scalar decay rate
    d_skip = dskip_ref[0].astype(jnp.float32)

    adt = a * dt                                # (Q,)
    cum = jnp.cumsum(adt)                       # (Q,)
    total = cum[-1]

    # intra-chunk: gate[i,j] = (C_i . B_j) * exp(cum_i - cum_j), j <= i
    li = cum[:, None] - cum[None, :]
    causal = jax.lax.broadcasted_iota(jnp.int32, li.shape, 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, li.shape, 1)
    decay = jnp.where(causal, jnp.exp(li), 0.0)
    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q)
    xdt = x * dt[:, None]                       # (Q, P)
    y = jax.lax.dot_general(
        scores * decay, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # inter-chunk: y += exp(cum_i) * C_i . state
    state = state_scr[...]                      # (N, P)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # state update: state' = exp(total) * state + sum_j exp(total - cum_j) B_j xdt_j
    rem = jnp.exp(total - cum)                  # (Q,)
    state_scr[...] = state * jnp.exp(total) + jax.lax.dot_general(
        bm * rem[:, None], xdt, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    y_ref[0, :, 0] = (y + x * d_skip).astype(y_ref.dtype)

    @pl.when(cb == num_chunks - 1)
    def _finalize():
        state_ref[0, 0] = state_scr[...].transpose(1, 0).astype(state_ref.dtype)


def _ssd_fwd_impl(x, dt, a_log, b, c, d_skip, *, chunk, interpret):
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    s_orig = s
    if s % chunk:
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        s += pad
    nc = s // chunk

    # flatten (B, H) into the leading grid dim; B/C shared across heads
    xt = x.transpose(0, 2, 1, 3).reshape(bsz * h, s, 1, p)
    dtt = dt.transpose(0, 2, 1).reshape(bsz * h, s, 1)
    a_rep = jnp.broadcast_to(a_log[None], (bsz, h)).reshape(bsz * h)
    d_rep = jnp.broadcast_to(d_skip[None], (bsz, h)).reshape(bsz * h)
    b_rep = jnp.broadcast_to(b[:, None], (bsz, h, s, n)).reshape(bsz * h, s, n)
    c_rep = jnp.broadcast_to(c[:, None], (bsz, h, s, n)).reshape(bsz * h, s, n)

    kernel = functools.partial(_kernel, chunk=chunk, num_chunks=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(bsz * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda g, cb: (g, cb, 0, 0)),
            pl.BlockSpec((1, chunk, 1), lambda g, cb: (g, cb, 0)),
            pl.BlockSpec((1,), lambda g, cb: (g,)),
            pl.BlockSpec((1, chunk, n), lambda g, cb: (g, cb, 0)),
            pl.BlockSpec((1, chunk, n), lambda g, cb: (g, cb, 0)),
            pl.BlockSpec((1,), lambda g, cb: (g,)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda g, cb: (g, cb, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda g, cb: (g, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz * h, s, 1, p), x.dtype),
            jax.ShapeDtypeStruct((bsz * h, 1, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, a_rep, b_rep, c_rep, d_rep)
    y = y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)[:, :s_orig]
    state = state.reshape(bsz, h, p, n)
    return y, state


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def ssd(x, dt, a_log, b, c, d_skip, chunk=256, interpret=False):
    return _ssd_fwd_impl(x, dt, a_log, b, c, d_skip, chunk=chunk,
                         interpret=interpret)


def _fwd(x, dt, a_log, b, c, d_skip, chunk, interpret):
    out = _ssd_fwd_impl(x, dt, a_log, b, c, d_skip, chunk=chunk,
                        interpret=interpret)
    return out, (x, dt, a_log, b, c, d_skip)


def _bwd(chunk, interpret, res, g):
    x, dt, a_log, b, c, d_skip = res
    _, vjp = jax.vjp(
        lambda *args: ref.ssd_chunked_xla(*args, chunk=chunk),
        x, dt, a_log, b, c, d_skip,
    )
    return vjp(g)


ssd.defvjp(_fwd, _bwd)
