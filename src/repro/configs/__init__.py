from repro.configs.base import (  # noqa: F401
    ArchConfig,
    SHAPES,
    get_arch,
    input_specs,
    list_archs,
    reduced,
    shape_applicable,
)
