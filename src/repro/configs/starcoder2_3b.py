"""StarCoder2-3B — GQA, RoPE, gelu MLP [arXiv:2402.19173; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2, head_dim=128,
    d_ff=12288, vocab=49152, mlp_type="gelu", rope_theta=1e6,
    grad_accum=2,
    source="arXiv:2402.19173; hf",
)
