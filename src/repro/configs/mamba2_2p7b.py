"""Mamba2-2.7B — SSD (state-space duality), attention-free
[arXiv:2405.21060; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, vocab=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=256,
    grad_accum=2,
    source="arXiv:2405.21060; unverified",
)
