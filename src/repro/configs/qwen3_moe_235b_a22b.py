"""Qwen3-MoE 235B-A22B — 128 experts top-8, qk_norm [hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936, mlp_type="swiglu", qk_norm=True, rope_theta=1e6,
    num_experts=128, experts_per_token=8, moe_d_ff=1536,
    moment_dtype="bfloat16",  # 235B total params
    moe_impl="group",  # §Perf B1: 15.2x memory-term win vs scan (EXPERIMENTS.md)
    moe_parallel="ep",  # §Perf B3: experts sharded over model — coll -49%, hbm -39%
    grad_accum=8,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)
