"""Mixtral-8x7B — 8 experts top-2, sliding-window attention
[arXiv:2401.04088; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000, mlp_type="swiglu", rope_theta=1e6,
    window=4096,
    num_experts=8, experts_per_token=2, moe_d_ff=14336,
    moe_impl="group",  # §Perf: 15.2x memory-term win vs scan (EXPERIMENTS.md)
    grad_accum=4,
    source="arXiv:2401.04088; hf",
)
