"""Qwen3-32B — qk_norm, GQA, decoupled head_dim [hf:Qwen/Qwen3-8B; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=25600, vocab=151936, mlp_type="swiglu", qk_norm=True, rope_theta=1e6,
    grad_accum=4,
    source="hf:Qwen/Qwen3-8B; hf",
)
