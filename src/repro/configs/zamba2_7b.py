"""Zamba2-7B — Mamba2 backbone + one shared attention block applied every 6
layers [arXiv:2411.15242; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32000, mlp_type="swiglu", rope_theta=1e4,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=256,
    hybrid_period=6,
    grad_accum=4,
    source="arXiv:2411.15242; unverified",
)
