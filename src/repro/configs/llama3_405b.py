"""Llama-3 405B — dense GQA, 128k vocab [arXiv:2407.21783; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b", family="dense",
    num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8, head_dim=128,
    d_ff=53248, vocab=128256, mlp_type="swiglu", rope_theta=5e5,
    moment_dtype="bfloat16",  # fp32 Adam for 405B does not fit 256x16GB (DESIGN.md)
    grad_accum=16,
    source="arXiv:2407.21783; unverified",
)
