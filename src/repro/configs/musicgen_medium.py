"""MusicGen-medium — decoder-only over EnCodec tokens, 4 codebooks
[arXiv:2306.05284; hf]. Modality frontend is a stub: input_specs() feeds
precomputed EnCodec frame token ids (B, S, 4)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="dense", modality="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24, head_dim=64,
    d_ff=6144, vocab=2048, mlp_type="gelu", rope_theta=1e4,
    num_codebooks=4,
    source="arXiv:2306.05284; hf",
)
