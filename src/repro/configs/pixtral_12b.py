"""Pixtral-12B — mistral-nemo backbone; pixtral-ViT frontend is a stub
supplying precomputed patch embeddings [hf:mistralai/Pixtral-12B-2409;
unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", family="dense", modality="image",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131072, mlp_type="swiglu", rope_theta=1e6,
    grad_accum=4,
    source="hf:mistralai/Pixtral-12B-2409; unverified",
)
