"""Architecture config system.

One ``ArchConfig`` per assigned architecture (exact numbers from the
assignment table, source tags in each ``<id>.py``). ``reduced()`` derives
the CPU-smoke-test variant; ``input_specs()`` builds the
ShapeDtypeStruct stand-ins used by the multi-pod dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid
    modality: str = "text"      # text | audio | image
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0           # explicit (qwen3/pixtral have head_dim*H != d_model)
    d_ff: int = 0
    vocab: int = 0
    mlp_type: str = "swiglu"    # swiglu | gelu
    qk_norm: bool = False
    rope_theta: float = 1e4
    window: int = 0             # sliding-window attention (0 = full)
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2): one shared attn block applied every `hybrid_period` layers
    hybrid_period: int = 0
    # audio (musicgen)
    num_codebooks: int = 0
    # numerics / perf knobs (hillclimbed per-cell; see EXPERIMENTS.md §Perf)
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "full"         # none | full | dots
    kernel_backend: str = "xla" # xla | pallas
    moment_dtype: str = "float32"  # optimizer moments (bf16 for 100B+)
    grad_accum: int = 1         # microbatch gradient accumulation
    # §Perf hillclimb knobs (EXPERIMENTS.md):
    moe_impl: str = "scan"      # scan | group | ragged (see models/moe.py)
    moe_parallel: str = "tp"    # tp (ff sharded) | ep (experts sharded, full ff)
    kv_cache_dtype: str = "bfloat16"  # bfloat16 | int8 (quantised decode cache)
    sp_block_outputs: bool = False  # constrain attn/mlp outputs S-sharded
    #   pre-residual -> GSPMD emits reduce-scatter instead of all-reduce
    cp_attention: bool = False      # sequence-parallel q (context parallel)
    #   instead of head-sharded q: kills the attention all-to-all storm
    source: str = ""

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Total parameters (for 6ND model-FLOPs and catalogue sizes)."""
        d, v = self.d_model, self.vocab
        n = 0
        n += v * d * (1 if self.tie_embeddings else 2)
        if self.modality == "audio":
            n += (self.num_codebooks - 1) * v * d  # extra codebook embeds+heads
            n += (self.num_codebooks - 1) * v * d
        per_layer = 0
        if self.family in ("dense", "moe"):
            hd = self.head_dim
            per_layer += d * hd * self.num_heads  # q
            per_layer += 2 * d * hd * self.num_kv_heads  # k, v
            per_layer += hd * self.num_heads * d  # o
            if self.is_moe:
                ff = self.moe_d_ff or self.d_ff
                per_layer += d * self.num_experts  # router
                per_layer += self.num_experts * 3 * d * ff
            else:
                mult = 3 if self.mlp_type == "swiglu" else 2
                per_layer += mult * d * self.d_ff
            per_layer += 2 * d  # norms
            n += self.num_layers * per_layer
        elif self.family == "ssm":
            n += self.num_layers * self._mamba_layer_params()
        elif self.family == "hybrid":
            n += self.num_layers * self._mamba_layer_params()
            # one shared attention+MLP block
            hd = self.head_dim
            shared = d * hd * self.num_heads * 2 + 2 * d * hd * self.num_kv_heads
            shared += 3 * d * self.d_ff + 2 * d
            n += shared
        n += d  # final norm
        return n

    def _mamba_layer_params(self) -> int:
        d, di, ns = self.d_model, self.d_inner, self.ssm_state
        h = self.ssm_heads
        in_proj = d * (2 * di + 2 * ns + h)
        conv = self.ssm_conv * (di + 2 * ns)
        out = di * d
        return in_proj + conv + out + 3 * h + 2 * d + di

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.param_count()
        ff = self.moe_d_ff or self.d_ff
        inactive = (
            self.num_layers
            * (self.num_experts - self.experts_per_token)
            * 3
            * self.d_model
            * ff
        )
        return self.param_count() - inactive


# --- assigned input shapes -----------------------------------------------------
SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

_REGISTRY = [
    "llama3_405b", "smollm_135m", "starcoder2_3b", "qwen3_32b",
    "musicgen_medium", "pixtral_12b", "mixtral_8x7b", "qwen3_moe_235b_a22b",
    "mamba2_2p7b", "zamba2_7b",
]

_ALIASES = {
    "llama3-405b": "llama3_405b", "smollm-135m": "smollm_135m",
    "starcoder2-3b": "starcoder2_3b", "qwen3-32b": "qwen3_32b",
    "musicgen-medium": "musicgen_medium", "pixtral-12b": "pixtral_12b",
    "mixtral-8x7b": "mixtral_8x7b", "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "mamba2-2.7b": "mamba2_2p7b", "zamba2-7b": "zamba2_7b",
}


def list_archs():
    return list(_REGISTRY)


def get_arch(name: str, **overrides) -> ArchConfig:
    mod_name = _ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.CONFIG
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def shape_applicable(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """long_500k requires sub-quadratic attention (DESIGN.md §5)."""
    if shape_name == "long_500k":
        sub_quadratic = cfg.family in ("ssm", "hybrid") or cfg.window > 0
        if not sub_quadratic:
            return False, "pure full-attention arch; 500k decode skipped per assignment"
    return True, ""


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Small same-family variant for CPU smoke tests."""
    return dataclasses.replace(
        cfg,
        num_layers=min(cfg.num_layers, 13 if cfg.family == "hybrid" else 2),
        d_model=256,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=64,
        d_ff=512,
        vocab=512,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        moe_d_ff=min(cfg.moe_d_ff, 256) if cfg.moe_d_ff else 0,
        ssm_state=min(cfg.ssm_state, 32) if cfg.ssm_state else 0,
        ssm_head_dim=32,
        ssm_chunk=16,
        window=min(cfg.window, 64) if cfg.window else 0,
        hybrid_period=min(cfg.hybrid_period, 6) if cfg.hybrid_period else 0,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        grad_accum=1,
    )


def input_specs(cfg: ArchConfig, shape_name: str, dtype=jnp.int32):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    if sh["kind"] in ("train", "prefill"):
        toks = (b, s, cfg.num_codebooks) if cfg.modality == "audio" else (b, s)
        specs = {"tokens": jax.ShapeDtypeStruct(toks, jnp.int32)}
        if sh["kind"] == "train":
            specs["labels"] = jax.ShapeDtypeStruct(
                (b, s, cfg.num_codebooks) if cfg.modality == "audio" else (b, s),
                jnp.int32,
            )
        if cfg.modality == "image":
            # stub frontend: precomputed patch embeddings replace token embeds
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, s, cfg.d_model), jnp.bfloat16
            )
        return specs
    # decode: one new token against a full KV/SSM cache of length s
    toks = (b, 1, cfg.num_codebooks) if cfg.modality == "audio" else (b, 1)
    specs = {"tokens": jax.ShapeDtypeStruct(toks, jnp.int32)}
    if cfg.modality == "image":
        specs["patch_embeds"] = jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.bfloat16)
    return specs
