"""Deterministic synthetic data pipeline.

Produces sharded global batches for any arch/shape without touching disk:
token streams are generated per (epoch, step, host-shard) from a counter-
based PRNG, so every host materialises exactly its own shard (no
broadcast), restarts are reproducible from the step index alone (no
iterator state in checkpoints), and elastic re-sharding is trivial —
data placement is a pure function of (step, shard_id, num_shards).

A real deployment swaps ``synthetic_batch`` for an array-record reader
with the same interface; everything downstream is unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, SHAPES


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    seed: int = 0


def _host_slice(global_batch: int, shard_id: int, num_shards: int):
    assert global_batch % num_shards == 0, (global_batch, num_shards)
    per = global_batch // num_shards
    return shard_id * per, per


def synthetic_batch(cfg: ArchConfig, dc: DataConfig, step: int,
                    shard_id: int = 0, num_shards: int = 1) -> dict:
    """Batch shard for one host. Pure function of (step, shard)."""
    start, per = _host_slice(dc.global_batch, shard_id, num_shards)
    # counter-based: every (step, row) pair gets its own fold
    rng = np.random.default_rng(
        np.random.SeedSequence([dc.seed, step, start])
    )
    shape = (per, dc.seq_len)
    if cfg.modality == "audio":
        shape = (per, dc.seq_len, cfg.num_codebooks)
    tokens = rng.integers(0, dc.vocab, size=shape, dtype=np.int32)
    labels = np.roll(tokens, -1, axis=1)
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    if cfg.modality == "image":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((per, dc.seq_len, cfg.d_model), dtype=np.float32),
            dtype=jnp.bfloat16,
        )
    return batch


def make_iterator(cfg: ArchConfig, dc: DataConfig, start_step: int = 0,
                  shard_id: int = 0, num_shards: int = 1) -> Iterator[dict]:
    step = start_step
    while True:
        yield synthetic_batch(cfg, dc, step, shard_id, num_shards)
        step += 1


def data_config_for_shape(cfg: ArchConfig, shape_name: str, **overrides) -> DataConfig:
    sh = SHAPES[shape_name]
    base = dict(seq_len=sh["seq_len"], global_batch=sh["global_batch"],
                vocab=cfg.vocab)
    base.update(overrides)
    return DataConfig(**base)
