"""Atomic, resumable checkpointing (no orbax in this environment).

Design for the 1000+-node case:
  * **atomicity** — write to ``step_N.tmp/`` then ``rename`` (POSIX-atomic),
    so a node failure mid-write never corrupts the restore point;
  * **auto-resume** — ``latest_step`` scans committed checkpoints only;
    ``restore`` never sees a partial write;
  * **sharded-friendly layout** — one ``.npy`` per pytree leaf keyed by
    tree path. On a multi-host cluster each host dumps only the
    addressable shards of its leaves into ``<leafkey>.shard<i>.npy``;
    here (single process) every leaf is fully addressable;
  * **retention** — keep the last ``keep`` checkpoints, GC the rest.

The trainer (`launch/train.py`) checkpoints on a cadence and restores on
startup, which together with the deterministic data pipeline gives full
fault-tolerant restart semantics.
"""
from __future__ import annotations

import json
import shutil
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir, step: int, tree, *, keep: int = 3, extra: dict | None = None):
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f"step_{step}.tmp"
    final = ckpt_dir / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat, _ = _flatten(tree)
    manifest = {"step": step, "leaves": sorted(flat), "extra": extra or {}}
    for key, leaf in flat.items():
        np.save(tmp / (key.replace("/", "__") + ".npy"), np.asarray(leaf))
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit
    _gc(ckpt_dir, keep)
    return final


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in ckpt_dir.iterdir()
        if p.name.startswith("step_") and not p.name.endswith(".tmp")
        and (p / "manifest.json").exists()
    ]
    return max(steps) if steps else None


def restore(ckpt_dir, step: int, like):
    """Restore into the structure (and dtypes) of ``like``."""
    path = Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((path / "manifest.json").read_text())
    flat, treedef = _flatten(like)
    assert sorted(flat) == manifest["leaves"], "checkpoint/model structure mismatch"
    restored = []
    for key in flat:  # insertion order == tree_flatten order
        arr = np.load(path / (key.replace("/", "__") + ".npy"))
        restored.append(jax.numpy.asarray(arr, dtype=flat[key].dtype))
    return jax.tree_util.tree_unflatten(treedef, restored), manifest["extra"]


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(
        (int(p.name.split("_")[1]), p)
        for p in ckpt_dir.iterdir()
        if p.name.startswith("step_") and not p.name.endswith(".tmp")
    )
    for _, p in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(p)
