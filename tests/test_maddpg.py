"""MADDPG algorithm mechanics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import env as env_lib, maddpg, networks
from repro.core.types import action_dim


def _setup(centralized=True, model_aware=True):
    p = env_lib.default_params(num_eds=4, num_models=3)
    cfg = maddpg.AlgoConfig(
        hidden=32, critic_hidden=32, batch_size=16, buffer_capacity=64,
        total_steps=40, warmup=8, update_every=4, n_envs=2,
        centralized_critic=centralized, model_aware=model_aware,
    )
    return p, cfg


def test_policy_action_shapes_and_ranges():
    p, cfg = _setup()
    ts = maddpg.init_state(jax.random.key(0), p, cfg)
    obs = jnp.zeros((p.num_eds, env_lib.obs_dim(p)))
    act = maddpg.policy_action(ts.actor, obs, p, cfg, jax.random.key(1), 1.0)
    assert act.target.shape == (p.num_eds,)
    assert bool(jnp.all((act.target >= 0) & (act.target <= p.num_ess)))
    assert bool(jnp.all((act.eta >= 0) & (act.eta <= 1)))
    assert set(np.unique(np.asarray(act.beta))) <= {0.0, 1.0}


def test_nomodel_masks_compat_and_downloads():
    p, cfg = _setup(model_aware=False)
    obs = jnp.ones((p.num_eds, env_lib.obs_dim(p)))
    masked = maddpg._mask_obs(obs, p, model_aware=False)
    import repro.core.baselines as bl
    sl = bl._obs_slices(p)
    assert bool(jnp.all(masked[:, sl["compat"][0]:sl["compat"][1]] == 0))
    ts = maddpg.init_state(jax.random.key(0), p, cfg)
    act = maddpg.policy_action(ts.actor, masked, p, cfg, jax.random.key(1), 1.0)
    assert bool(jnp.all(act.beta == 0))


def test_soft_update_interpolates():
    a = {"w": jnp.zeros((2,))}
    b = {"w": jnp.ones((2,))}
    out = networks.soft_update(a, b, tau=0.25)
    np.testing.assert_allclose(out["w"], jnp.full((2,), 0.25))


def test_update_reduces_critic_loss_on_fixed_batch():
    p, cfg = _setup()
    ts = maddpg.init_state(jax.random.key(0), p, cfg)
    key = jax.random.key(1)
    d, g, a = env_lib.obs_dim(p), env_lib.global_dim(p), action_dim(p.num_ess)
    m, b = p.num_eds, cfg.batch_size
    ks = jax.random.split(key, 7)
    batch = {
        "obs": jax.random.normal(ks[0], (b, m, d)),
        "act": jax.random.uniform(ks[1], (b, m, a)),
        "rew": jax.random.normal(ks[2], (b, m)),
        "next_obs": jax.random.normal(ks[3], (b, m, d)),
        "done": jnp.zeros((b,)),
        "gstate": jax.random.uniform(ks[4], (b, g)),
        "next_gstate": jax.random.uniform(ks[5], (b, g)),
    }

    def critic_loss(ts_):
        next_act = jax.vmap(
            lambda o: maddpg._soft_action(ts_.target_actor, o, p, cfg)
        )(batch["next_obs"])
        q_next = networks.stacked_apply(
            ts_.target_critic,
            maddpg._critic_inputs(batch["next_obs"], batch["next_gstate"],
                                  next_act, p, cfg),
        )[..., 0]
        y = jnp.swapaxes(batch["rew"], 0, 1) + cfg.gamma * q_next
        q = networks.stacked_apply(
            ts_.critic,
            maddpg._critic_inputs(batch["obs"], batch["gstate"], batch["act"],
                                  p, cfg),
        )[..., 0]
        return float(jnp.mean((q - y) ** 2))

    before = critic_loss(ts)
    ts2 = ts
    for _ in range(20):
        ts2 = maddpg.update(ts2, batch, key, p, cfg)
    after = critic_loss(ts2)
    assert after < before


def test_saddpg_critic_input_is_local():
    p, cfg = _setup(centralized=False)
    assert maddpg.critic_in_dim(p, cfg) == env_lib.obs_dim(p) + action_dim(p.num_ess)
    p2, cfg2 = _setup(centralized=True)
    assert maddpg.critic_in_dim(p2, cfg2) > maddpg.critic_in_dim(p, cfg)


def test_train_short_run_finishes_and_metrics_finite():
    p, cfg = _setup()
    ts, metrics = maddpg.train_jit(jax.random.key(0), p, cfg)
    assert metrics["reward"].shape == (cfg.total_steps,)
    assert bool(jnp.all(jnp.isfinite(metrics["reward"])))
    assert bool(jnp.all(jnp.isfinite(metrics["completion"])))
