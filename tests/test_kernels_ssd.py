"""Pallas SSD kernel + XLA chunked path vs the recurrent oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ssd_scan import ssd

CASES = [
    # B, S, H, P, N, chunk, dtype
    (2, 128, 4, 32, 16, 32, jnp.float32),
    (1, 256, 2, 64, 32, 64, jnp.float32),
    (2, 96, 4, 32, 16, 32, jnp.float32),   # ragged seq (pad path)
    (1, 128, 8, 16, 8, 16, jnp.float32),
    (1, 128, 2, 32, 16, 32, jnp.bfloat16),
]


def _inputs(key, b, s, h, p, n, dtype):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(dtype)
    a_log = (jax.random.normal(ks[2], (h,)) * 0.5).astype(jnp.float32)
    bb = jax.random.normal(ks[3], (b, s, n), dtype)
    cc = jax.random.normal(ks[4], (b, s, n), dtype)
    d_skip = jnp.ones((h,), jnp.float32)
    return x, dt, a_log, bb, cc, d_skip


@pytest.mark.parametrize("b,s,h,p,n,chunk,dtype", CASES)
def test_ssd_kernel_matches_recurrence(b, s, h, p, n, chunk, dtype):
    args = _inputs(jax.random.key(s + h), b, s, h, p, n, dtype)
    y1, s1 = ssd(*args, chunk, True)
    y2, s2 = ref.ssd_naive(*args)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(
        y1.astype(jnp.float32), y2.astype(jnp.float32), atol=tol, rtol=tol
    )
    np.testing.assert_allclose(s1, s2, atol=tol, rtol=tol)


@pytest.mark.parametrize("chunk", [16, 32, 128])
def test_ssd_xla_chunked_matches_recurrence(chunk):
    args = _inputs(jax.random.key(0), 2, 128, 4, 32, 16, jnp.float32)
    y1, s1 = ref.ssd_chunked_xla(*args, chunk=chunk)
    y2, s2 = ref.ssd_naive(*args)
    np.testing.assert_allclose(y1, y2, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(s1, s2, atol=5e-4, rtol=5e-4)


def test_ssd_decode_continues_prefill():
    """Running S-1 steps then one ssd_decode step == full recurrence."""
    b, s, h, p, n = 1, 64, 2, 16, 8
    x, dt, a_log, bb, cc, d_skip = _inputs(jax.random.key(4), b, s, h, p, n,
                                           jnp.float32)
    y_full, state_full = ref.ssd_naive(x, dt, a_log, bb, cc, d_skip)
    _, state_prefix = ref.ssd_naive(
        x[:, :-1], dt[:, :-1], a_log, bb[:, :-1], cc[:, :-1], d_skip
    )
    y_last, state_last = ref.ssd_decode_naive(
        state_prefix, x[:, -1], dt[:, -1], a_log, bb[:, -1], cc[:, -1], d_skip
    )
    np.testing.assert_allclose(y_last, y_full[:, -1], atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(state_last, state_full, atol=1e-5, rtol=1e-5)


def test_ssd_grads_flow():
    args = _inputs(jax.random.key(9), 1, 64, 2, 16, 8, jnp.float32)
    g = jax.grad(lambda x: ssd(x, *args[1:], 16, True)[0].sum())(args[0])
    assert g.shape == args[0].shape
    assert not bool(jnp.any(jnp.isnan(g)))
