"""Serving-path correctness: token-by-token decode must reproduce the
teacher-forced forward for every family, and prefill must hand off a cache
that decode can continue exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import lm

FAMILIES = ["smollm_135m", "mixtral_8x7b", "mamba2_2p7b", "zamba2_7b"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_teacher_forced(arch):
    cfg = reduced(get_arch(arch))
    params = lm.init_params(jax.random.key(0), cfg)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    logits_tf, _ = jax.jit(lambda p, t: lm.forward(p, t, cfg))(params, toks)
    cache = lm.init_cache(cfg, B, S)
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg))
    outs = []
    for i in range(S):
        _, logits, cache = step(params, cache, toks[:, i : i + 1], jnp.int32(i))
        outs.append(logits[:, 0])
    np.testing.assert_allclose(
        jnp.stack(outs, 1), logits_tf, atol=5e-5, rtol=5e-5
    )


@pytest.mark.parametrize("arch", ["smollm_135m", "mamba2_2p7b"])
def test_prefill_then_decode_continues(arch):
    """prefill(toks[:p]) cache + decode of later tokens == teacher-forced."""
    cfg = reduced(get_arch(arch))
    params = lm.init_params(jax.random.key(0), cfg)
    B, S, P = 1, 8, 5
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab)
    logits_tf, _ = lm.forward(params, toks, cfg)

    _, last_logits, cache = lm.prefill(params, toks[:, :P], cfg)
    np.testing.assert_allclose(
        last_logits[:, 0], logits_tf[:, P - 1], atol=5e-5, rtol=5e-5
    )
    # attention prefill caches are sized P; decode needs room — re-seat into
    # a full-size cache buffer
    full = lm.init_cache(cfg, B, S)

    def seat(dst, src):
        if src.shape == dst.shape:
            return src.astype(dst.dtype)
        pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
        return jnp.pad(src, pad).astype(dst.dtype)

    cache = jax.tree.map(lambda d, s: seat(d, s), full, cache)
    for i in range(P, S):
        _, logits, cache = lm.decode_step(
            params, cache, toks[:, i : i + 1], jnp.int32(i), cfg
        )
        np.testing.assert_allclose(
            logits[:, 0], logits_tf[:, i], atol=5e-5, rtol=5e-5
        )


def test_sliding_window_cache_matches_full_for_short_seq():
    cfg = reduced(get_arch("mixtral_8x7b"))
    assert cfg.window > 0
    params = lm.init_params(jax.random.key(0), cfg)
    B, S = 1, 8  # S < window: ring cache must behave like a full cache
    toks = jax.random.randint(jax.random.key(3), (B, S), 0, cfg.vocab)
    logits_tf, _ = lm.forward(params, toks, cfg)
    cache = lm.init_cache(cfg, B, S)
    for i in range(S):
        _, logits, cache = lm.decode_step(
            params, cache, toks[:, i : i + 1], jnp.int32(i), cfg
        )
        np.testing.assert_allclose(
            logits[:, 0], logits_tf[:, i], atol=5e-5, rtol=5e-5
        )
