"""End-to-end behaviour tests for the paper's system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import env as env_lib, evaluate, maddpg
from repro.core.catalog import build_catalog
from repro.core.router import EdgeServer, ModelAwareRouter, Request


@pytest.mark.slow
def test_maddpg_training_beats_random():
    """A short MADDPG-MATO run must outperform the random policy."""
    p = env_lib.default_params(num_eds=6, num_models=3)
    cfg = maddpg.AlgoConfig(
        total_steps=1200, warmup=300, update_every=5, batch_size=128,
        n_envs=4, hidden=64, critic_hidden=128, explore_decay_steps=800,
    )
    ts, metrics = maddpg.train_jit(jax.random.key(0), p, cfg)
    trained = evaluate.evaluate_policy(
        jax.random.key(9), "actor", p, cfg=cfg, params=ts.actor, episodes=24
    )
    rand = evaluate.evaluate_policy(jax.random.key(9), "random", p, episodes=24)
    assert trained["reward"] > rand["reward"]
    assert trained["completion"] >= rand["completion"]


@pytest.mark.slow
def test_reward_improves_during_training():
    p = env_lib.default_params(num_eds=6, num_models=3)
    cfg = maddpg.AlgoConfig(
        total_steps=1200, warmup=300, update_every=5, batch_size=128,
        n_envs=4, hidden=64, critic_hidden=128, explore_decay_steps=800,
    )
    _, metrics = maddpg.train_jit(jax.random.key(1), p, cfg)
    r = np.asarray(metrics["reward"])
    assert r[-200:].mean() > r[:200].mean()


def test_catalog_grounds_paper_model_set():
    """Eq. 2's abstract {I_i, X_i} maps to the real assigned archs."""
    cat = build_catalog()
    assert len(cat) == 10
    sizes = {e.name: e.size_bits for e in cat}
    # llama3-405b must dwarf smollm by ~3 orders of magnitude
    assert sizes["llama3_405b"] / sizes["smollm_135m"] > 1000
    e = next(x for x in cat if x.name == "smollm_135m")
    # switch latency over 1 Gb/s backhaul: bf16 weights / rate (eq. 7)
    assert abs(e.switch_latency(1e9) - e.size_bits / 1e9) < 1e-9


def test_router_prefers_resident_models():
    cat = build_catalog(["smollm_135m", "starcoder2_3b", "mamba2_2p7b"])
    servers = [
        EdgeServer("a", 1e14, 2, 1e8, 1e9, resident=[0, 1]),
        EdgeServer("b", 1e14, 2, 1e8, 1e9, resident=[2]),
    ]
    router = ModelAwareRouter(servers, cat)
    choice, _ = router.route(Request(model=2, prompt_bits=1e5, gen_tokens=4))
    assert choice == 1  # model 2 resident on server b
    choice, _ = router.route(Request(model=0, prompt_bits=1e5, gen_tokens=4))
    assert choice == 0


def test_router_lru_eviction():
    cat = build_catalog(["smollm_135m", "starcoder2_3b", "mamba2_2p7b"])
    srv = EdgeServer("a", 1e14, 2, 1e8, 1e9, resident=[0, 1])
    router = ModelAwareRouter([srv], cat)
    router.route(Request(model=1, prompt_bits=1e5, gen_tokens=1))  # touch 1
    router.route(Request(model=2, prompt_bits=1e5, gen_tokens=1))  # insert 2
    assert set(srv.resident) == {1, 2}  # 0 was LRU


def test_model_aware_beats_blind_on_switch_costs():
    """With big models and a slow backhaul, pricing switches must win."""
    cat = build_catalog(["starcoder2_3b", "mamba2_2p7b"])
    import numpy as np

    def run(model_aware):
        servers = [
            EdgeServer("a", 1e14, 1, 1e8, 2e8, resident=[0]),
            EdgeServer("b", 1e14, 1, 1e8, 2e8, resident=[1]),
        ]
        router = ModelAwareRouter(servers, cat)
        rng = np.random.default_rng(0)
        total = 0.0
        for _ in range(30):
            req = Request(int(rng.integers(0, 2)), 1e5, 2)
            if model_aware:
                _, lat = router.route(req)
            else:  # blind round-robin
                srv = servers[router.clock % 2]
                lat = router._candidate_latency(srv, req)
                router.clock += 1
                if req.model not in srv.resident:
                    srv.resident = [req.model]
                srv.queue_tokens += req.gen_tokens
            total += lat
            router.drain(2.0)
        return total / 30

    assert run(True) < run(False)
