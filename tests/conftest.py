import os
import subprocess
import sys
from pathlib import Path

import pytest

# tests run on the single real CPU device (the 512-device farm is ONLY for
# the dry-run entry point, which sets XLA_FLAGS itself before jax init)
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
os.environ.setdefault("JAX_PLATFORMS", "cpu")


# ---------------------------------------------------------------------------
# multi-device harness (docs/sharding.md)
#
# XLA fixes the host device count at first jax init, so a test that needs 8
# devices cannot get them inside an already-initialised 1-device process.
# Tests marked ``multidevice`` are therefore re-run ONCE, all together, in a
# child pytest under XLA_FLAGS=--xla_force_host_platform_device_count=8; in
# the parent each marked test then reports skip (child green) or fail (child
# red, with the child's tail attached). When the current process already
# sees >= 8 devices — the child itself, or a real multi-device host — the
# marked tests simply run in-process.
# ---------------------------------------------------------------------------
_FORCED_ENV = "REPRO_FORCED_HOST_DEVICES"
_FORCED_COUNT = 8
_child_result: dict = {}


def _run_multidevice_child() -> dict:
    if not _child_result:
        env = dict(os.environ)
        env[_FORCED_ENV] = str(_FORCED_COUNT)
        flags = env.get("XLA_FLAGS", "")
        env["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={_FORCED_COUNT}"
        ).strip()
        proc = subprocess.run(
            [sys.executable, "-m", "pytest", "-q", "-m", "multidevice",
             "-p", "no:cacheprovider", str(Path(__file__).parent)],
            cwd=str(Path(__file__).resolve().parents[1]),
            env=env, capture_output=True, text=True,
        )
        _child_result.update(
            rc=proc.returncode,
            tail=(proc.stdout + proc.stderr)[-4000:],
        )
    return _child_result


@pytest.fixture(autouse=True)
def _multidevice_gate(request):
    if request.node.get_closest_marker("multidevice") is None:
        return
    if os.environ.get(_FORCED_ENV):
        return  # we ARE the forced child: run in-process
    import jax

    if jax.device_count() >= _FORCED_COUNT:
        return  # a real multi-device host: run in-process
    res = _run_multidevice_child()
    if res["rc"] == 0:
        pytest.skip(
            "passed in the one-shot forced-8-device child run "
            "(XLA_FLAGS=--xla_force_host_platform_device_count=8)"
        )
    pytest.fail(
        "the forced-8-device child run failed "
        f"(exit {res['rc']}); child tail:\n{res['tail']}",
        pytrace=False,
    )
