import os
import sys
from pathlib import Path

# tests run on the single real CPU device (the 512-device farm is ONLY for
# the dry-run entry point, which sets XLA_FLAGS itself before jax init)
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
os.environ.setdefault("JAX_PLATFORMS", "cpu")
