"""Mesh-sharded router units: layout, mesh construction, validation.

Single-device tests for the ``core.mesh_router`` plumbing — the
cell-major ``FleetState`` layout helpers in ``core.batch_router``, the
``make_mesh`` device-count validation (regression: it used to build a
mesh silently over a SUBSET of the platform's devices), the sharded
entry point's own validation errors, and D=1 bitwise equivalence
against the plain ``route_batch`` scan (the multi-device matrix lives
in ``tests/test_multicell_router.py`` under the ``multidevice``
marker; see docs/sharding.md).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import batch_router as br
from repro.core import mesh_router as mr
from repro.core import networks, policies
from repro.core.catalog import build_catalog
from repro.core.router import CLOUD_CELL, EdgeServer
from repro.distributed import sharding
from repro.launch import serve
from repro.workloads.simulate import simulate

CATALOG = build_catalog(
    ["smollm_135m", "starcoder2_3b", "mamba2_2p7b", "musicgen_medium"]
)


def _edge(i, cell, rng, drain=0.0):
    return EdgeServer(
        name=f"c{cell}-es{i}",
        flops_per_s=float(rng.uniform(5e13, 2e14)),
        cache_slots=2,
        uplink_bps=float(rng.uniform(5e7, 2e8)),
        backhaul_bps=float(rng.uniform(5e8, 2e9)),
        resident=list(rng.choice(len(CATALOG), size=2, replace=False)),
        cell=cell,
        drain_rate=drain,
    )


def _fleet(rng, n_cells, per_cell, cloud=False, drain=0.0):
    fleet = [_edge(i, c, rng, drain)
             for c in range(n_cells) for i in range(per_cell)]
    if cloud:
        fleet.append(serve.make_cloud_server(CATALOG, drain_rate=drain))
    return fleet


def _stream(rng, n, n_cells, dtype=jnp.float32):
    return br.RequestBatch(
        model=jnp.asarray(rng.integers(0, len(CATALOG), n), jnp.int32),
        prompt_bits=jnp.asarray(rng.uniform(1e5, 1e6, n), dtype),
        gen_tokens=jnp.asarray(rng.integers(1, 64, n).astype(float), dtype),
        cell=jnp.asarray(rng.integers(0, n_cells, n), jnp.int32),
        arrival_s=jnp.asarray(np.cumsum(rng.exponential(2e-3, n)), dtype),
    )


def _assert_bitwise(st_a, out_a, st_b, out_b):
    """Full-outcome + full-state bitwise equality (LRU compared only on
    resident entries: a non-resident slot's clock is unobservable)."""
    np.testing.assert_array_equal(np.asarray(out_a.choice),
                                  np.asarray(out_b.choice))
    np.testing.assert_array_equal(np.asarray(out_a.latency),
                                  np.asarray(out_b.latency))
    np.testing.assert_array_equal(np.asarray(out_a.hit),
                                  np.asarray(out_b.hit))
    np.testing.assert_array_equal(np.asarray(st_a.resident),
                                  np.asarray(st_b.resident))
    np.testing.assert_array_equal(np.asarray(st_a.queue_tokens),
                                  np.asarray(st_b.queue_tokens))
    assert int(st_a.clock) == int(st_b.clock)
    if st_a.time_s is not None:
        np.testing.assert_array_equal(np.asarray(st_a.time_s),
                                      np.asarray(st_b.time_s))
    res = np.asarray(st_a.resident)
    np.testing.assert_array_equal(np.asarray(st_a.last_use)[res],
                                  np.asarray(st_b.last_use)[res])


# ---------------------------------------------------------------------------
# make_mesh device-count validation (regression)
# ---------------------------------------------------------------------------
def test_make_mesh_rejects_mismatched_axis_shapes():
    """It must be impossible to build a mesh whose axis shapes silently
    cover only part of the devices it draws from."""
    n = len(jax.devices())
    with pytest.raises(ValueError, match=f"require {n + 1} device"):
        sharding.make_mesh((n + 1,), ("x",))
    with pytest.raises(ValueError, match="devices argument supplies"):
        sharding.make_mesh((2,), ("x",), devices=jax.devices()[:1])
    # exact-match shapes still build, with and without explicit devices
    assert sharding.make_mesh((n,), ("x",)).shape["x"] == n
    m = sharding.make_mesh((1,), ("x",), devices=jax.devices()[:1])
    assert m.shape["x"] == 1


def test_cells_mesh_smoke():
    mesh = mr.cells_mesh(1)
    assert mesh.axis_names == ("cells",)
    assert mesh.shape["cells"] == 1
    with pytest.raises(ValueError):
        mr.cells_mesh(len(jax.devices()) + 1)


# ---------------------------------------------------------------------------
# cell-major layout helpers
# ---------------------------------------------------------------------------
def test_cell_layout_of_canonical_fleet():
    rng = np.random.default_rng(0)
    params, _ = br.fleet_from_servers(_fleet(rng, 3, 4, cloud=True), CATALOG)
    layout = br.cell_layout(params)
    assert layout == br.CellLayout(num_cells=3, per_cell=4, num_cloud=1)
    assert layout.num_edge == 12 and layout.num_servers == 13


def test_cell_layout_untopologied_fleet_is_one_cell():
    rng = np.random.default_rng(1)
    params, _ = br.fleet_from_servers(
        [_edge(i, 0, rng) for i in range(5)], CATALOG
    )
    layout = br.cell_layout(params)
    assert (layout.num_cells, layout.per_cell, layout.num_cloud) in {
        (1, 5, 0),  # params.cell is None or all zeros — both are one cell
    }


def test_cell_layout_rejects_non_cell_major():
    rng = np.random.default_rng(2)
    interleaved = [_edge(0, 0, rng), _edge(0, 1, rng),
                   _edge(1, 0, rng), _edge(1, 1, rng)]
    params, _ = br.fleet_from_servers(interleaved, CATALOG)
    with pytest.raises(ValueError, match="contiguous ascending"):
        br.cell_layout(params)

    unequal = [_edge(0, 0, rng), _edge(1, 0, rng), _edge(0, 1, rng)]
    params, _ = br.fleet_from_servers(unequal, CATALOG)
    with pytest.raises(ValueError, match="equal-sized"):
        br.cell_layout(params)

    mid_cloud = [_edge(0, 0, rng), serve.make_cloud_server(CATALOG),
                 _edge(0, 1, rng)]
    params, _ = br.fleet_from_servers(mid_cloud, CATALOG)
    with pytest.raises(ValueError, match="CLOUD_CELL servers must trail"):
        br.cell_layout(params)


def test_cell_major_order_and_permute_roundtrip():
    """A shuffled fleet permutes into a valid cell-major layout, and the
    permutation is a pure relabelling of every per-server array."""
    rng = np.random.default_rng(3)
    fleet = _fleet(rng, 3, 2, cloud=True)
    perm = rng.permutation(len(fleet))
    shuffled = [fleet[i] for i in perm]
    params, state = br.fleet_from_servers(shuffled, CATALOG)
    with pytest.raises(ValueError):
        br.cell_layout(params)
    order = br.cell_major_order(np.asarray(params.cell))
    p2, s2 = br.permute_fleet(params, state, order)
    layout = br.cell_layout(p2)
    assert (layout.num_cells, layout.per_cell, layout.num_cloud) == (3, 2, 1)
    np.testing.assert_array_equal(np.asarray(p2.flops_per_s),
                                  np.asarray(params.flops_per_s)[order])
    np.testing.assert_array_equal(np.asarray(s2.resident),
                                  np.asarray(state.resident)[order])


def test_local_block_params_relabel():
    rng = np.random.default_rng(4)
    params, _ = br.fleet_from_servers(_fleet(rng, 3, 2, cloud=True), CATALOG)
    layout = br.cell_layout(params)
    local = br.local_block_params(params, layout, 1)
    cell = np.asarray(local.cell)
    np.testing.assert_array_equal(cell, [0, 0, CLOUD_CELL])
    np.testing.assert_array_equal(np.asarray(local.flops_per_s)[:2],
                                  np.asarray(params.flops_per_s)[2:4])
    np.testing.assert_array_equal(np.asarray(local.flops_per_s)[2:],
                                  np.asarray(params.flops_per_s)[6:])


# ---------------------------------------------------------------------------
# sharded entry-point validation
# ---------------------------------------------------------------------------
def test_sharded_rejects_drain_tokens():
    rng = np.random.default_rng(5)
    params, state = br.fleet_from_servers(_fleet(rng, 2, 2), CATALOG)
    reqs = _stream(rng, 16, 2)
    with pytest.raises(ValueError, match="drain_tokens"):
        mr.route_batch_sharded(params, state, reqs, 4.0, num_devices=1)


def test_sharded_requires_full_cloud_residency():
    rng = np.random.default_rng(6)
    fleet = _fleet(rng, 2, 2)
    partial_cloud = serve.make_cloud_server(CATALOG)
    partial_cloud.resident = [0, 1]  # missing models 2, 3
    partial_cloud.cache_slots = 2
    fleet.append(partial_cloud)
    params, state = br.fleet_from_servers(fleet, CATALOG)
    reqs = _stream(rng, 16, 2)
    with pytest.raises(ValueError, match="cloud"):
        mr.route_batch_sharded(params, state, reqs, num_devices=1)


def test_sharded_empty_batch_delegates_to_plain():
    rng = np.random.default_rng(7)
    params, state = br.fleet_from_servers(_fleet(rng, 2, 2), CATALOG)
    reqs = _stream(rng, 0, 2)
    st, out = mr.route_batch_sharded(params, state, reqs, num_devices=1)
    assert out.choice.shape == (0,)
    np.testing.assert_array_equal(np.asarray(st.resident),
                                  np.asarray(state.resident))


# ---------------------------------------------------------------------------
# D=1 bitwise equivalence vs the plain scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["greedy", "load", "drain"])
@pytest.mark.parametrize("chunk", [None, 16])
def test_sharded_single_device_bitwise_vs_plain(policy, chunk):
    rng = np.random.default_rng(8)
    params, state = br.fleet_from_servers(_fleet(rng, 4, 3), CATALOG)
    reqs = _stream(rng, 150, 4)
    st_p, out_p = br.route_batch(params, state, reqs, policy=policy,
                                 chunk=chunk)
    st_s, out_s = mr.route_batch_sharded(params, state, reqs, policy=policy,
                                         chunk=chunk, num_devices=1)
    _assert_bitwise(st_p, out_p, st_s, out_s)


def test_sharded_auto_permutes_shuffled_fleet():
    """A non-cell-major fleet routes through an internal permutation and
    comes back in CALLER order — bitwise equal to the plain scan on the
    same shuffled fleet."""
    rng = np.random.default_rng(9)
    fleet = _fleet(rng, 3, 2)
    perm = rng.permutation(len(fleet))
    params, state = br.fleet_from_servers([fleet[i] for i in perm], CATALOG)
    reqs = _stream(rng, 120, 3)
    st_p, out_p = br.route_batch(params, state, reqs)
    st_s, out_s = mr.route_batch_sharded(params, state, reqs, num_devices=1)
    _assert_bitwise(st_p, out_p, st_s, out_s)


# ---------------------------------------------------------------------------
# cell-block actor policy
# ---------------------------------------------------------------------------
def _toy_actor(spec):
    sizes = [policies.obs_dim(spec), 16, 16, spec.num_ess + 3]
    return networks.stacked_init(jax.random.key(0), 2, sizes)


def test_actor_policy_for_cell_blocks_matches_global():
    """One block-local actor closure under the mesh == the global-fleet
    actor closure on the plain path, decision for decision."""
    rng = np.random.default_rng(10)
    params, state = br.fleet_from_servers(_fleet(rng, 3, 4, cloud=True),
                                          CATALOG)
    spec = policies.ObsSpec(num_models=len(CATALOG), num_ess=4, num_cells=1,
                            task_bits_hi=8e6, rho_hi=400.0, f_cc=2e14,
                            f_ed_hi=5e9, area_m=500.0)
    actor = _toy_actor(spec)
    pol_global = policies.make_actor_policy(actor, spec, params)
    pol_local = policies.actor_policy_for_cell_blocks(actor, spec, params)
    reqs = _stream(rng, 96, 3)
    st_p, out_p = br.route_batch(params, state, reqs, policy=pol_global)
    st_s, out_s = mr.route_batch_sharded(params, state, reqs,
                                         policy=pol_local, num_devices=1)
    _assert_bitwise(st_p, out_p, st_s, out_s)


def test_actor_policy_for_cell_blocks_rejects_bad_geometry():
    rng = np.random.default_rng(11)
    params, _ = br.fleet_from_servers(_fleet(rng, 3, 4, cloud=True), CATALOG)
    spec = policies.ObsSpec(num_models=len(CATALOG), num_ess=4, num_cells=1,
                            task_bits_hi=8e6, rho_hi=400.0, f_cc=2e14,
                            f_ed_hi=5e9, area_m=500.0)
    actor = _toy_actor(spec)
    with pytest.raises(ValueError, match="single-cell-trained"):
        policies.actor_policy_for_cell_blocks(
            actor, spec._replace(num_cells=3), params
        )
    with pytest.raises(ValueError, match="cell blocks hold 4"):
        policies.actor_policy_for_cell_blocks(
            actor, spec._replace(num_ess=3), params
        )


# ---------------------------------------------------------------------------
# mesh knobs on the simulator and the serve CLI
# ---------------------------------------------------------------------------
def test_simulate_mesh_windows_match_plain_single_call():
    """Drain-free + cloud-free: sharded windowed simulate == ONE plain
    route_batch call on the whole stream (windowing is a pure
    re-chunking; each window is bitwise vs the plain scan)."""
    rng = np.random.default_rng(12)
    params, state = br.fleet_from_servers(_fleet(rng, 3, 2), CATALOG)
    reqs = _stream(rng, 150, 3)
    st_p, out_p = br.route_batch(params, state, reqs)
    st_s, out_s, series = simulate(params, state, reqs, window_requests=64,
                                   num_devices=1)
    _assert_bitwise(st_p, out_p, st_s, out_s)
    assert len(series.requests) == 3


def test_simulate_rejects_drain_tokens_under_mesh():
    rng = np.random.default_rng(13)
    params, state = br.fleet_from_servers(_fleet(rng, 2, 2), CATALOG)
    reqs = _stream(rng, 16, 2)
    with pytest.raises(ValueError, match="drain_tokens"):
        simulate(params, state, reqs, drain_tokens=4.0, num_devices=1)


def test_serve_mesh_flag_smoke():
    stats = serve.serve(num_requests=12, n_servers=2, execute=False,
                        n_cells=2, mesh=1)
    assert stats["requests"] == 12
    assert stats["completion_rate"] == 1.0


# ---------------------------------------------------------------------------
# seed-pinned fuzz (hypothesis-free twin of test_properties.py's
# test_all_router_paths_agree — same driver, fixed draws, so the
# path-matrix invariant runs in CI without hypothesis installed)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed,n_cells,per_cell,cloud,policy,chunk", [
    (1001, 3, 2, False, "greedy", 16),
    (1002, 2, 3, True, "drain", 48),
    (1003, 4, 1, False, "load", 16),
])
def test_router_paths_agree_seeded(seed, n_cells, per_cell, cloud, policy,
                                   chunk):
    from fuzz_paths import check_router_paths_agree

    check_router_paths_agree(seed, n_cells, per_cell, cloud, policy, chunk)


@pytest.mark.parametrize(
    "seed,n_cells,per_cell,cloud,policy,chunk,deadline,spill,outage", [
        (1101, 3, 2, False, "greedy", 16, True, False, False),
        (1102, 2, 2, False, "drain", 16, False, True, False),
        (1103, 3, 1, False, "greedy", 48, False, False, True),
        (1104, 4, 2, False, "drain", 16, True, True, True),
        (1105, 2, 3, True, "load", 48, True, False, True),
    ])
def test_router_paths_agree_robustness_seeded(seed, n_cells, per_cell, cloud,
                                              policy, chunk, deadline, spill,
                                              outage):
    """Seed-pinned twin of the hypothesis sweep's robustness knobs: SLO
    deadline column, neighbour-cell spill adjacency and server-outage
    mask through every router path, rejection causes included."""
    from fuzz_paths import check_router_paths_agree

    check_router_paths_agree(seed, n_cells, per_cell, cloud, policy, chunk,
                             deadline=deadline, spill=spill, outage=outage)


@pytest.mark.parametrize(
    "seed,n_cells,per_cell,cloud,policy,chunk,eta,beta,deadline,outage,spill",
    [
        (1201, 3, 2, False, "greedy", 16, "mixed", False, False, False,
         False),
        (1202, 2, 3, True, "drain", 48, False, "mixed", False, False, False),
        (1203, 3, 1, False, "greedy", 16, "zero", "refuse", True, False,
         False),
        (1204, 2, 2, True, "drain", 16, "mixed", "mixed", False, True,
         False),
        (1205, 4, 2, False, "load", 48, "mixed", "download", True, False,
         True),
    ])
def test_router_paths_agree_eta_beta_seeded(seed, n_cells, per_cell, cloud,
                                            policy, chunk, eta, beta,
                                            deadline, outage, spill):
    """Seed-pinned twin of the hypothesis sweep's eq. 16 action knobs:
    partial-offload eta columns and download-refusal beta columns (plus
    their interactions with the robustness knobs) through every router
    path — scan, chunked, speculative, mesh-sharded — against the
    scalar oracle."""
    from fuzz_paths import check_router_paths_agree

    check_router_paths_agree(seed, n_cells, per_cell, cloud, policy, chunk,
                             deadline=deadline, spill=spill, outage=outage,
                             eta=eta, beta=beta)
