"""Pallas flash attention vs the pure-jnp oracle: shape/dtype sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention

CASES = [
    # B, S, H, KV, D, window, dtype
    (2, 256, 4, 2, 64, 0, jnp.float32),
    (1, 512, 8, 8, 128, 0, jnp.float32),
    (2, 256, 4, 1, 64, 0, jnp.float32),
    (2, 256, 4, 4, 64, 128, jnp.float32),
    (1, 256, 2, 2, 128, 0, jnp.bfloat16),
    (1, 384, 6, 3, 64, 256, jnp.float32),  # ragged heads, window
]


@pytest.mark.parametrize("b,s,h,kv,d,window,dtype", CASES)
def test_flash_matches_oracle(b, s, h, kv, d, window, dtype):
    ks = jax.random.split(jax.random.key(b * s + h), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    out = flash_attention(q, k, v, True, window, 0, 128, 128, True)
    exp = ref.attention_naive(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        out.astype(jnp.float32), exp.astype(jnp.float32), atol=tol, rtol=tol
    )


def test_flash_q_offset_matches_suffix():
    """Computing only the last 128 queries with q_offset == full attention tail."""
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 4, 64))
    v = jax.random.normal(ks[2], (1, 256, 4, 64))
    full = ref.attention_naive(q, k, v, causal=True)
    tail = flash_attention(q[:, 128:], k, v, True, 0, 128, 128, 128, True)
    np.testing.assert_allclose(tail, full[:, 128:], atol=2e-5, rtol=2e-5)


def test_flash_grad_matches_oracle_grad():
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))

    def f_kernel(q, k, v):
        return (flash_attention(q, k, v, True, 0, 0, 128, 128, True) ** 2).sum()

    def f_ref(q, k, v):
        return (ref.attention_naive(q, k, v, causal=True) ** 2).sum()

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)


def test_xla_flash_path_matches_oracle():
    """attention_xla (the dry-run backend) vs naive, incl. chunked path."""
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (2, 1024, 4, 64))
    k = jax.random.normal(ks[1], (2, 1024, 2, 64))
    v = jax.random.normal(ks[2], (2, 1024, 2, 64))
    out = ref.attention_xla(q, k, v, causal=True, q_chunk=256)
    exp = ref.attention_naive(q, k, v, causal=True)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)
