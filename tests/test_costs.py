"""Pin every printed equation of paper §II.C (eqs. 3-15)."""
import jax.numpy as jnp
import pytest

from repro.core import costs


def test_eq3_local_latency():
    # T = x(1-eta)rho / f
    assert costs.local_latency(8e6, 0.25, 100.0, 2e9) == pytest.approx(
        8e6 * 0.75 * 100 / 2e9
    )


def test_eq4_local_energy_faithful_has_no_eta():
    e1 = costs.local_energy_faithful(8e6, 0.0, 100.0, 1e-28, 2e9)
    e2 = costs.local_energy_faithful(8e6, 0.9, 100.0, 1e-28, 2e9)
    assert e1 == e2  # printed equation ignores eta
    assert e1 == pytest.approx(1e-28 * (2e9) ** 2 * 8e6 * 100)


def test_eq4_corrected_scales_with_local_share():
    e = costs.local_energy_corrected(8e6, 0.25, 100.0, 1e-28, 2e9)
    assert e == pytest.approx(1e-28 * 4e18 * 8e6 * 0.75 * 100)


def test_eq5_eq6_transmission():
    t = costs.trans_latency(8e6, 0.5, 50e6)
    assert t == pytest.approx(4e6 / 50e6)
    assert costs.trans_energy(0.5, t) == pytest.approx(0.5 * t)


def test_eq7_eq8_switching():
    t = costs.switch_latency(200 * 8e6, 1e9)
    assert t == pytest.approx(1.6)
    assert costs.switch_energy(2.0, t) == pytest.approx(3.2)


def test_eq9_eq10_edge():
    assert costs.edge_latency(8e6, 0.5, 100.0, 7e9) == pytest.approx(
        4e6 * 100 / 7e9
    )
    e = costs.edge_energy_corrected(8e6, 0.5, 100.0, 1e-29, 7e9)
    assert e == pytest.approx(1e-29 * 49e18 * 4e6 * 100)


def test_eq11_12_totals_additive():
    assert costs.edge_total_latency(1.0, 2.0, 3.0) == 6.0
    assert costs.edge_total_energy(1.0, 2.0, 3.0) == 6.0


def test_eq13_14_max_semantics():
    assert costs.total_latency(2.0, 3.0) == 3.0
    assert costs.total_energy(2.0, 3.0, faithful=True) == 3.0  # max as printed
    assert costs.total_energy(2.0, 3.0, faithful=False) == 5.0  # physical sum


def test_eq15_objective():
    assert costs.objective(2.0, 4.0, 0.5, 0.5) == 3.0


def test_shannon_rate_monotone_in_distance():
    g_near = costs.channel_gain(100.0, 1e-3, 3.0)
    g_far = costs.channel_gain(500.0, 1e-3, 3.0)
    r_near = costs.shannon_rate(20e6, 0.5, g_near, 3.98e-21)
    r_far = costs.shannon_rate(20e6, 0.5, g_far, 3.98e-21)
    assert float(r_near) > float(r_far) > 0
