"""MoE layer: ragged dispatch vs dense per-expert oracle; shard_map parity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import moe


def _cfg():
    return reduced(get_arch("mixtral_8x7b"))


def _dense_oracle(params, x, cfg):
    """Compute EVERY expert densely, then combine with the same top-k gates."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, cfg.experts_per_token)
    gate = gate / gate.sum(-1, keepdims=True)
    outs = []
    for e in range(cfg.num_experts):
        g = xt @ params["wg"][e]
        u = xt @ params["wu"][e]
        outs.append((jax.nn.silu(g) * u) @ params["wd"][e])
    outs = jnp.stack(outs, axis=1)  # (T, E, d)
    mask = jax.nn.one_hot(ids, cfg.num_experts)  # (T, k, E)
    combined = jnp.einsum("tk,tke,ted->td", gate, mask, outs)
    return combined.reshape(b, s, d)


def test_ragged_matches_dense_oracle():
    cfg = _cfg()
    params = moe.moe_init(jax.random.key(0), cfg)
    params = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    import dataclasses
    cfg = dataclasses.replace(cfg, compute_dtype="float32", param_dtype="float32")
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y, aux = moe.moe_apply_local(params, x, cfg)
    y_ref = _dense_oracle(params, x, cfg)
    np.testing.assert_allclose(y, y_ref, atol=1e-4, rtol=1e-4)
    assert float(aux) > 0


def test_shard_map_path_matches_local():
    cfg = _cfg()
    import dataclasses
    cfg = dataclasses.replace(cfg, compute_dtype="float32", param_dtype="float32")
    params = moe.moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y_local, aux_local = moe.moe_apply_local(params, x, cfg)
    from repro.distributed import sharding

    mesh = sharding.make_mesh((1, 1), ("data", "model"))
    y_sm, aux_sm = jax.jit(lambda p, xx: moe.moe_apply(p, xx, cfg, mesh=mesh))(
        params, x
    )
    np.testing.assert_allclose(y_sm, y_local, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(aux_sm), float(aux_local), rtol=1e-5)


def test_aux_loss_uniform_router_is_one():
    """With perfectly uniform routing the Switch aux loss equals 1."""
    cfg = _cfg()
    import dataclasses
    cfg = dataclasses.replace(cfg, compute_dtype="float32", param_dtype="float32")
    params = moe.moe_init(jax.random.key(0), cfg)
    params = dict(params, router=jnp.zeros_like(params["router"]))
    # zero router logits -> uniform probs; top-k tie-broken by index, but
    # p_mean is exactly uniform -> aux = E * sum_e f_e / E = sum_e f_e = 1
    x = jax.random.normal(jax.random.key(2), (2, 32, cfg.d_model))
    _, aux = moe.moe_apply_local(params, x, cfg)
    np.testing.assert_allclose(float(aux), 1.0, rtol=1e-5)


def test_grads_flow_through_router_and_experts():
    cfg = _cfg()
    params = moe.moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(3), (1, 8, cfg.d_model))

    def loss(p):
        y, aux = moe.moe_apply_local(p, x, cfg)
        return (y.astype(jnp.float32) ** 2).sum() + aux

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["wg"].astype(jnp.float32)).sum()) > 0
