"""HLO analyzer: while-trip-count multipliers must recover true costs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze, xla_cost_analysis


def test_flat_module_matches_xla_cost_analysis():
    g = jax.jit(lambda a, b: (a @ b) @ b)
    co = g.lower(
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
    ).compile()
    res = analyze(co.as_text())
    ca = xla_cost_analysis(co)
    np.testing.assert_allclose(res["flops"], ca["flops"], rtol=0.05)


def test_scanned_matmul_trip_count():
    L, D = 7, 128

    def f(x, w):
        return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]

    co = jax.jit(f).lower(
        jax.ShapeDtypeStruct((D, D), jnp.float32),
        jax.ShapeDtypeStruct((L, D, D), jnp.float32),
    ).compile()
    res = analyze(co.as_text())
    np.testing.assert_allclose(res["flops"], L * 2 * D**3, rtol=0.02)


def test_nested_scan_multiplies():
    L, R, D = 5, 3, 64

    def f(x, w):
        def outer(c, wi):
            def inner(c2, _):
                return c2 @ wi, None
            return jax.lax.scan(inner, c, None, length=R)[0], None
        return jax.lax.scan(outer, x, w)[0]

    co = jax.jit(f).lower(
        jax.ShapeDtypeStruct((D, D), jnp.float32),
        jax.ShapeDtypeStruct((L, D, D), jnp.float32),
    ).compile()
    res = analyze(co.as_text())
    np.testing.assert_allclose(res["flops"], L * R * 2 * D**3, rtol=0.02)


def test_collectives_counted_with_ring_formula():
    from repro.distributed import sharding

    mesh = sharding.make_mesh((1,), ("x",))

    def f(a):
        return sharding.shard_map(
            lambda v: jax.lax.psum(v, "x"), mesh=mesh,
            in_specs=jax.sharding.PartitionSpec("x"),
            out_specs=jax.sharding.PartitionSpec(),
        )(a)

    co = jax.jit(f).lower(jax.ShapeDtypeStruct((8,), jnp.float32)).compile()
    res = analyze(co.as_text())
    # single-device group => zero traffic
    assert res["collective_bytes"] == 0.0
