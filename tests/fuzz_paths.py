"""Shared fuzz driver: one random routing scenario, every router path.

NOT a test module — ``tests/test_properties.py`` drives it through
hypothesis (random scenarios) and ``tests/test_mesh_router.py`` through
a fixed seed list (so the same invariant is exercised in environments
without hypothesis installed, CI included).

``check_router_paths_agree`` builds a random fleet + request stream
from one seed and asserts the full path matrix agrees:

* plain scan, chunked, speculative-chunked and mesh-sharded (D=1)
  ``route_batch`` all produce identical decisions, residency, LRU
  clocks and (to ulps, where re-association applies) latencies/queues;
* for the policies the scalar ``ModelAwareRouter`` implements
  ("greedy", "drain"), the scan's choices equal the oracle's.

Fleets are drain-free and either cloud-free or single-cell-stream
(cloud on): the configurations where the sharded window is bitwise
(see ``core.mesh_router``). The sharded path is compared BITWISE
against the scan — any drift is a real bug, not tolerance noise.

The robustness knobs (``docs/robustness.md``) fuzz the same invariant
with ``deadline=True`` (a mixed-SLO deadline column), ``spill=True``
(a random zero-diagonal neighbour-cell adjacency; the stream collapses
to cell 0, the regime where the sharded full-replication spill path is
bitwise) and ``outage=True`` (a ~30% random server-outage mask). All
paths must then ALSO agree on the per-request rejection cause, and the
oracle's ``last_cause`` must match bit for bit. The knobs draw from a
separate rng stream, so knob-free calls regenerate the exact historical
scenarios of the seed-pinned tests.

The eq. 16 action knobs fuzz the partial-offload / download-refusal
semantics on top: ``eta`` is ``False`` (column absent — the bitwise
no-op contract), ``"zero"`` (everything local: zero edge share) or
``"mixed"`` (per-request ratios from {0, ¼, ½, ¾, 1} — exactly
representable in every float width, so the f32 batch columns and the
f64 oracle see identical values; a per-request local compute rate
rides along for the eq. 3 term); ``beta`` is ``False``,
``"download"`` (every miss fetches — identical decisions to today,
exercised as such), ``"refuse"`` (every miss re-prices resident-only)
or ``"mixed"``. The eta/beta draws come AFTER the robustness draws on
the knob rng, so every historical knob combination regenerates bit for
bit.
"""
import copy

import numpy as np
import jax.numpy as jnp

from repro.core import batch_router as br
from repro.core import mesh_router as mr
from repro.core.catalog import build_catalog
from repro.core.router import EdgeServer, ModelAwareRouter, Request

CATALOG = build_catalog(
    ["smollm_135m", "starcoder2_3b", "mamba2_2p7b", "musicgen_medium"]
)
_ORACLE_POLICIES = ("greedy", "drain")


def _random_scenario(seed, n_cells, per_cell, cloud):
    rng = np.random.default_rng(seed)
    fleet = [
        EdgeServer(
            name=f"c{c}-es{i}",
            flops_per_s=float(rng.uniform(5e13, 2e14)),
            cache_slots=int(rng.integers(1, 3)),
            uplink_bps=float(rng.uniform(5e7, 2e8)),
            backhaul_bps=float(rng.uniform(5e8, 2e9)),
            resident=list(rng.choice(len(CATALOG),
                                     size=int(rng.integers(1, 3)),
                                     replace=False)),
            cell=c,
        )
        for c in range(n_cells)
        for i in range(per_cell)
    ]
    if cloud:
        from repro.launch.serve import make_cloud_server

        fleet.append(make_cloud_server(CATALOG))
    n = 60
    # cloud on -> single-contributor stream (cell 0 only): the regime
    # where the sharded window is bitwise even through the cloud column
    req_cells = rng.integers(0, 1 if cloud else n_cells, n)
    stream = (
        rng.integers(0, len(CATALOG), n),
        rng.uniform(1e5, 1e6, n),
        rng.integers(1, 64, n),
        req_cells,
        np.cumsum(rng.exponential(2e-3, n)),
    )
    return fleet, stream


def check_router_paths_agree(seed, n_cells, per_cell, cloud, policy, chunk,
                             deadline=False, spill=False, outage=False,
                             eta=False, beta=False):
    fleet, (models, bits, toks, cells, arrivals) = _random_scenario(
        seed, n_cells, per_cell, cloud
    )
    # the robustness knobs draw from their OWN rng so knob-free calls
    # regenerate the exact scenarios the seed-pinned tests expect
    knob_rng = np.random.default_rng([seed, 0xB0B])
    n = len(models)
    dl = adj = out_mask = None
    if deadline:  # mixed SLO classes: tight / loose / none
        dl = knob_rng.choice([0.05, 5.0, np.inf], size=n)
    if spill:
        # zero-diagonal adjacency; stream collapses to cell 0 — the
        # single-bucket regime where the sharded spill path is bitwise
        adj = knob_rng.random((n_cells, n_cells)) < 0.6
        np.fill_diagonal(adj, False)
        cells = np.zeros_like(cells)
    if outage:
        out_mask = knob_rng.random(len(fleet)) < 0.3
    # eq. 16 action knobs draw AFTER the robustness knobs (module
    # docstring): historical knob combinations regenerate bit for bit
    eta_col = loc_col = beta_col = None
    if eta:  # quarter ratios are exact in f32 AND f64: batch == oracle
        eta_col = (np.zeros(n) if eta == "zero" else
                   knob_rng.choice([0.0, 0.25, 0.5, 0.75, 1.0], size=n))
        loc_col = knob_rng.uniform(5e11, 5e12, n).astype(
            np.float32).astype(np.float64)
    if beta:
        beta_col = {"download": np.ones(n, bool),
                    "refuse": np.zeros(n, bool)}.get(
                        beta, knob_rng.random(n) < 0.5)
    params, state0 = br.fleet_from_servers(fleet, CATALOG)
    if spill:
        params = params._replace(spill=jnp.asarray(adj))
    outage_arr = None if out_mask is None else jnp.asarray(out_mask)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
        cell=jnp.asarray(cells, jnp.int32),
        arrival_s=jnp.asarray(arrivals, jnp.float32),
        deadline_s=None if dl is None else jnp.asarray(dl, jnp.float32),
        eta=None if eta_col is None else jnp.asarray(eta_col, jnp.float32),
        beta=None if beta_col is None else jnp.asarray(beta_col),
        local_flops_per_s=(None if loc_col is None
                           else jnp.asarray(loc_col, jnp.float32)),
    )
    st_scan, out_scan = br.route_batch(params, state0, reqs, policy=policy,
                                       outage=outage_arr)
    runs = {
        "chunked": br.route_batch(params, state0, reqs, policy=policy,
                                  chunk=chunk, speculative=False,
                                  outage=outage_arr),
        "speculative": br.route_batch(params, state0, reqs, policy=policy,
                                      chunk=chunk, speculative=True,
                                      outage=outage_arr),
        "sharded": mr.route_batch_sharded(params, state0, reqs,
                                          policy=policy, num_devices=1,
                                          outage=outage_arr),
        "sharded-chunked": mr.route_batch_sharded(params, state0, reqs,
                                                  policy=policy, chunk=chunk,
                                                  num_devices=1,
                                                  outage=outage_arr),
    }
    resident = np.asarray(st_scan.resident)
    for name, (st, out) in runs.items():
        np.testing.assert_array_equal(np.asarray(out.choice),
                                      np.asarray(out_scan.choice),
                                      err_msg=name)
        np.testing.assert_array_equal(np.asarray(out.cause),
                                      np.asarray(out_scan.cause),
                                      err_msg=name)
        np.testing.assert_array_equal(np.asarray(out.hit),
                                      np.asarray(out_scan.hit), err_msg=name)
        np.testing.assert_array_equal(np.asarray(st.resident), resident,
                                      err_msg=name)
        np.testing.assert_array_equal(
            np.asarray(st.last_use)[resident],
            np.asarray(st_scan.last_use)[resident], err_msg=name)
        assert int(st.clock) == int(st_scan.clock), name
        if name == "sharded":  # same inner path: bitwise, no tolerance
            np.testing.assert_array_equal(np.asarray(out.latency),
                                          np.asarray(out_scan.latency))
            np.testing.assert_array_equal(np.asarray(st.queue_tokens),
                                          np.asarray(st_scan.queue_tokens))
        else:  # chunked commits re-associate the eq. 9 sums: ulps
            np.testing.assert_allclose(np.asarray(out.latency),
                                       np.asarray(out_scan.latency),
                                       rtol=1e-5, err_msg=name)
            np.testing.assert_allclose(np.asarray(st.queue_tokens),
                                       np.asarray(st_scan.queue_tokens),
                                       rtol=1e-5, err_msg=name)

    if policy in _ORACLE_POLICIES:
        oracle_fleet = copy.deepcopy(fleet)
        if out_mask is not None:
            for srv, down in zip(oracle_fleet, out_mask):
                srv.outaged = bool(down)
        router = ModelAwareRouter(oracle_fleet, CATALOG, policy=policy,
                                  spill=adj)
        sc_choice, sc_cause = [], []
        for i, (m, b, t, c, a) in enumerate(
                zip(models, bits, toks, cells, arrivals)):
            sc_choice.append(router.route(Request(
                int(m), float(b), int(t), cell=int(c), arrival_s=float(a),
                deadline_s=None if dl is None else float(dl[i]),
                eta=None if eta_col is None else float(eta_col[i]),
                beta=None if beta_col is None else bool(beta_col[i]),
                local_flops_per_s=(None if loc_col is None
                                   else float(loc_col[i])),
            ))[0])
            sc_cause.append(router.last_cause)
        np.testing.assert_array_equal(np.asarray(out_scan.choice),
                                      np.array(sc_choice))
        np.testing.assert_array_equal(np.asarray(out_scan.cause),
                                      np.array(sc_cause))
