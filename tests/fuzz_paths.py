"""Shared fuzz driver: one random routing scenario, every router path.

NOT a test module — ``tests/test_properties.py`` drives it through
hypothesis (random scenarios) and ``tests/test_mesh_router.py`` through
a fixed seed list (so the same invariant is exercised in environments
without hypothesis installed, CI included).

``check_router_paths_agree`` builds a random fleet + request stream
from one seed and asserts the full path matrix agrees:

* plain scan, chunked, speculative-chunked and mesh-sharded (D=1)
  ``route_batch`` all produce identical decisions, residency, LRU
  clocks and (to ulps, where re-association applies) latencies/queues;
* for the policies the scalar ``ModelAwareRouter`` implements
  ("greedy", "drain"), the scan's choices equal the oracle's.

Fleets are drain-free and either cloud-free or single-cell-stream
(cloud on): the configurations where the sharded window is bitwise
(see ``core.mesh_router``). The sharded path is compared BITWISE
against the scan — any drift is a real bug, not tolerance noise.
"""
import copy

import numpy as np
import jax.numpy as jnp

from repro.core import batch_router as br
from repro.core import mesh_router as mr
from repro.core.catalog import build_catalog
from repro.core.router import EdgeServer, ModelAwareRouter, Request

CATALOG = build_catalog(
    ["smollm_135m", "starcoder2_3b", "mamba2_2p7b", "musicgen_medium"]
)
_ORACLE_POLICIES = ("greedy", "drain")


def _random_scenario(seed, n_cells, per_cell, cloud):
    rng = np.random.default_rng(seed)
    fleet = [
        EdgeServer(
            name=f"c{c}-es{i}",
            flops_per_s=float(rng.uniform(5e13, 2e14)),
            cache_slots=int(rng.integers(1, 3)),
            uplink_bps=float(rng.uniform(5e7, 2e8)),
            backhaul_bps=float(rng.uniform(5e8, 2e9)),
            resident=list(rng.choice(len(CATALOG),
                                     size=int(rng.integers(1, 3)),
                                     replace=False)),
            cell=c,
        )
        for c in range(n_cells)
        for i in range(per_cell)
    ]
    if cloud:
        from repro.launch.serve import make_cloud_server

        fleet.append(make_cloud_server(CATALOG))
    n = 60
    # cloud on -> single-contributor stream (cell 0 only): the regime
    # where the sharded window is bitwise even through the cloud column
    req_cells = rng.integers(0, 1 if cloud else n_cells, n)
    stream = (
        rng.integers(0, len(CATALOG), n),
        rng.uniform(1e5, 1e6, n),
        rng.integers(1, 64, n),
        req_cells,
        np.cumsum(rng.exponential(2e-3, n)),
    )
    return fleet, stream


def check_router_paths_agree(seed, n_cells, per_cell, cloud, policy, chunk):
    fleet, (models, bits, toks, cells, arrivals) = _random_scenario(
        seed, n_cells, per_cell, cloud
    )
    params, state0 = br.fleet_from_servers(fleet, CATALOG)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
        cell=jnp.asarray(cells, jnp.int32),
        arrival_s=jnp.asarray(arrivals, jnp.float32),
    )
    st_scan, out_scan = br.route_batch(params, state0, reqs, policy=policy)
    runs = {
        "chunked": br.route_batch(params, state0, reqs, policy=policy,
                                  chunk=chunk, speculative=False),
        "speculative": br.route_batch(params, state0, reqs, policy=policy,
                                      chunk=chunk, speculative=True),
        "sharded": mr.route_batch_sharded(params, state0, reqs,
                                          policy=policy, num_devices=1),
        "sharded-chunked": mr.route_batch_sharded(params, state0, reqs,
                                                  policy=policy, chunk=chunk,
                                                  num_devices=1),
    }
    resident = np.asarray(st_scan.resident)
    for name, (st, out) in runs.items():
        np.testing.assert_array_equal(np.asarray(out.choice),
                                      np.asarray(out_scan.choice),
                                      err_msg=name)
        np.testing.assert_array_equal(np.asarray(out.hit),
                                      np.asarray(out_scan.hit), err_msg=name)
        np.testing.assert_array_equal(np.asarray(st.resident), resident,
                                      err_msg=name)
        np.testing.assert_array_equal(
            np.asarray(st.last_use)[resident],
            np.asarray(st_scan.last_use)[resident], err_msg=name)
        assert int(st.clock) == int(st_scan.clock), name
        if name == "sharded":  # same inner path: bitwise, no tolerance
            np.testing.assert_array_equal(np.asarray(out.latency),
                                          np.asarray(out_scan.latency))
            np.testing.assert_array_equal(np.asarray(st.queue_tokens),
                                          np.asarray(st_scan.queue_tokens))
        else:  # chunked commits re-associate the eq. 9 sums: ulps
            np.testing.assert_allclose(np.asarray(out.latency),
                                       np.asarray(out_scan.latency),
                                       rtol=1e-5, err_msg=name)
            np.testing.assert_allclose(np.asarray(st.queue_tokens),
                                       np.asarray(st_scan.queue_tokens),
                                       rtol=1e-5, err_msg=name)

    if policy in _ORACLE_POLICIES:
        router = ModelAwareRouter(copy.deepcopy(fleet), CATALOG,
                                  policy=policy)
        sc_choice = [
            router.route(Request(int(m), float(b), int(t), cell=int(c),
                                 arrival_s=float(a)))[0]
            for m, b, t, c, a in zip(models, bits, toks, cells, arrivals)
        ]
        np.testing.assert_array_equal(np.asarray(out_scan.choice),
                                      np.array(sc_choice))
