"""Multi-cell fleets + time-based drain vs the scalar oracle — exact.

The block-diagonal cell mask (in-cell servers + the fleet-wide
``CLOUD_CELL`` fallback column) and the wall-clock queue drain
(``drain_rate * dt`` folded into the scan carry) must reproduce the
scalar ``ModelAwareRouter`` request for request, for C in {1, 2, 4}
cells — same choices, residency, LRU clocks, queues and fleet clock.
The time-based drain is additionally pinned against a hand-computed
queue trace, and ``drain_rate == 0`` must reproduce the synchronous
(PR 1) behaviour bit for bit.
"""
import copy

import numpy as np
import pytest
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import batch_router as br
from repro.core.catalog import build_catalog
from repro.core.router import CLOUD_CELL, EdgeServer, ModelAwareRouter, Request
from repro.launch.serve import make_cloud_server, make_multicell_fleet

CATALOG = build_catalog(
    ["smollm_135m", "starcoder2_3b", "mamba2_2p7b", "musicgen_medium"]
)


def _random_multicell_fleet(rng, n_cells, per_cell, cache_slots=2,
                            drain_hi=40.0, cloud=True):
    fleet = [
        EdgeServer(
            name=f"c{c}-es{i}",
            flops_per_s=float(rng.uniform(5e13, 2e14)),
            cache_slots=cache_slots,
            uplink_bps=float(rng.uniform(5e7, 2e8)),
            backhaul_bps=float(rng.uniform(5e8, 2e9)),
            resident=list(
                rng.choice(len(CATALOG), size=cache_slots, replace=False)
            ),
            cell=c,
            drain_rate=float(rng.uniform(0.0, drain_hi)),
        )
        for c in range(n_cells)
        for i in range(per_cell)
    ]
    if cloud:
        fleet.append(
            make_cloud_server(
                CATALOG, drain_rate=float(rng.uniform(0.0, 2.0 * drain_hi))
            )
        )
    return fleet


def _random_stream(rng, n, n_cells, rate=500.0):
    return (
        rng.integers(0, len(CATALOG), n),
        rng.uniform(1e5, 1e6, n),
        rng.integers(1, 64, n),
        rng.integers(0, n_cells, n),
        np.cumsum(rng.exponential(1.0 / rate, n)),
    )


def _run_scalar(fleet, models, bits, toks, cells, arrivals):
    router = ModelAwareRouter(copy.deepcopy(fleet), CATALOG)
    choices, lats = [], []
    for m, b, t, c, a in zip(models, bits, toks, cells, arrivals):
        ch, l = router.route(
            Request(int(m), float(b), int(t), cell=int(c), arrival_s=float(a))
        )
        choices.append(ch)
        lats.append(l)
    return router, np.array(choices), np.array(lats)


def _run_batched(fleet, models, bits, toks, cells, arrivals, dtype):
    params, state = br.fleet_from_servers(fleet, CATALOG)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, dtype),
        gen_tokens=jnp.asarray(toks, dtype),
        cell=jnp.asarray(cells, jnp.int32),
        arrival_s=jnp.asarray(arrivals, dtype),
    )
    return br.route_batch(params, state, reqs)


def _assert_fleet_state_matches(router, state):
    resident = np.asarray(state.resident)
    last_use = np.asarray(state.last_use)
    for i, srv in enumerate(router.servers):
        assert set(np.nonzero(resident[i])[0]) == set(srv.resident), i
        for m in srv.resident:
            if m in srv.last_use:
                assert last_use[i, m] == srv.last_use[m], (i, m)
    np.testing.assert_allclose(
        np.asarray(state.queue_tokens),
        np.array([s.queue_tokens for s in router.servers]),
        rtol=1e-6, atol=1e-9,
    )
    np.testing.assert_allclose(float(state.time_s), router.time_s, rtol=1e-6)


@pytest.mark.parametrize("seed,n_cells,per_cell", [
    (0, 1, 4), (1, 2, 3), (2, 4, 2), (3, 4, 4),
])
def test_multicell_matches_scalar_oracle_exactly(seed, n_cells, per_cell):
    """x64: C-cell fleets with cloud + time drain match the oracle."""
    with enable_x64():
        rng = np.random.default_rng(seed)
        fleet = _random_multicell_fleet(rng, n_cells, per_cell)
        models, bits, toks, cells, arrivals = _random_stream(
            rng, 300, n_cells
        )
        router, sc_choice, sc_lat = _run_scalar(
            fleet, models, bits, toks, cells, arrivals
        )
        state, out = _run_batched(
            fleet, models, bits, toks, cells, arrivals, jnp.float64
        )
        np.testing.assert_array_equal(np.asarray(out.choice), sc_choice)
        np.testing.assert_allclose(np.asarray(out.latency), sc_lat,
                                   rtol=1e-12, atol=0.0)
        _assert_fleet_state_matches(router, state)


@pytest.mark.parametrize("seed,n_cells", [(10, 2), (11, 4)])
def test_float32_multicell_same_decisions(seed, n_cells):
    """The f32 serving path agrees on every choice and residency set."""
    rng = np.random.default_rng(seed)
    fleet = _random_multicell_fleet(rng, n_cells, 3)
    models, bits, toks, cells, arrivals = _random_stream(rng, 400, n_cells)
    router, sc_choice, _ = _run_scalar(
        fleet, models, bits, toks, cells, arrivals
    )
    state, out = _run_batched(
        fleet, models, bits, toks, cells, arrivals, jnp.float32
    )
    np.testing.assert_array_equal(np.asarray(out.choice), sc_choice)
    resident = np.asarray(state.resident)
    for i, srv in enumerate(router.servers):
        assert set(np.nonzero(resident[i])[0]) == set(srv.resident), i


def test_choices_respect_cell_boundaries():
    """No request ever lands on an out-of-cell edge server."""
    rng = np.random.default_rng(5)
    fleet = _random_multicell_fleet(rng, 4, 3)
    models, bits, toks, cells, arrivals = _random_stream(rng, 500, 4)
    _, out = _run_batched(
        fleet, models, bits, toks, cells, arrivals, jnp.float32
    )
    srv_cell = np.array([s.cell for s in fleet])
    chosen = srv_cell[np.asarray(out.choice)]
    assert np.all((chosen == cells) | (chosen == CLOUD_CELL))
    # the cell-starved layout must actually exercise the cloud column
    assert np.any(chosen == CLOUD_CELL) or len(set(cells)) == 1


def test_score_matrix_masks_out_of_cell_servers():
    """(B, N) scores are +inf exactly on the out-of-cell, non-cloud pairs."""
    rng = np.random.default_rng(6)
    fleet = _random_multicell_fleet(rng, 3, 2)
    params, state = br.fleet_from_servers(fleet, CATALOG)
    models, bits, toks, cells, _ = _random_stream(rng, 40, 3)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
        cell=jnp.asarray(cells, jnp.int32),
    )
    scores = np.asarray(br.score_matrix(params, state, reqs))
    srv_cell = np.array([s.cell for s in fleet])
    visible = (srv_cell[None, :] == cells[:, None]) | (
        srv_cell[None, :] == CLOUD_CELL
    )
    assert np.all(np.isinf(scores[~visible]))
    assert np.all(np.isfinite(scores[visible]))


def test_time_drain_matches_hand_computed_trace():
    """Queue decay over a synthetic wall-clock schedule, checked by hand.

    Two single-server cells force every choice, so the queues follow
    arithmetic we can do on paper:
      r0 cell0 t=1.0 gen=10:   dt=1.0  q=(0,0)          -> commit (10, 0)
      r1 cell1 t=2.0 gen=5:    dt=1.0  q=(10-2, 0)      -> commit (8, 5)
      r2 cell0 t=4.5 gen=3:    dt=2.5  q=(8-5, 5-7.5|0) -> commit (6, 0)
      r3 cell0 t=4.5 gen=1:    dt=0.0  q=(6, 0)         -> commit (7, 0)
    """
    with enable_x64():
        mk = lambda cell, drain: EdgeServer(
            name=f"s{cell}", flops_per_s=1e14, cache_slots=len(CATALOG),
            uplink_bps=1e8, backhaul_bps=1e9,
            resident=list(range(len(CATALOG))), cell=cell, drain_rate=drain,
        )
        fleet = [mk(0, 2.0), mk(1, 3.0)]
        params, state = br.fleet_from_servers(fleet, CATALOG)
        reqs = br.RequestBatch(
            model=jnp.zeros((4,), jnp.int32),
            prompt_bits=jnp.full((4,), 1e5, jnp.float64),
            gen_tokens=jnp.asarray([10.0, 5.0, 3.0, 1.0], jnp.float64),
            cell=jnp.asarray([0, 1, 0, 0], jnp.int32),
            arrival_s=jnp.asarray([1.0, 2.0, 4.5, 4.5], jnp.float64),
        )
        state, out = br.route_batch(params, state, reqs)
        np.testing.assert_array_equal(np.asarray(out.choice), [0, 1, 0, 0])
        np.testing.assert_allclose(
            np.asarray(state.queue_tokens), [7.0, 0.0], rtol=0, atol=0
        )
        assert float(state.time_s) == 4.5


def test_drain_rate_zero_is_exactly_synchronous():
    """drain_rate == 0 with arrival stamps == the PR 1 no-drain path, bit
    for bit (choices, latencies, queues, residency, LRU clocks)."""
    rng = np.random.default_rng(14)
    fleet = _random_multicell_fleet(rng, 2, 3, drain_hi=0.0)
    assert all(s.drain_rate == 0.0 for s in fleet)
    models, bits, toks, cells, arrivals = _random_stream(rng, 250, 2)

    params, state = br.fleet_from_servers(fleet, CATALOG)
    base = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
        cell=jnp.asarray(cells, jnp.int32),
    )
    timed = base._replace(arrival_s=jnp.asarray(arrivals, jnp.float32))

    state_sync, out_sync = br.route_batch(params, state, base)
    state_time, out_time = br.route_batch(params, state, timed)

    np.testing.assert_array_equal(np.asarray(out_sync.choice),
                                  np.asarray(out_time.choice))
    np.testing.assert_array_equal(np.asarray(out_sync.latency),
                                  np.asarray(out_time.latency))
    np.testing.assert_array_equal(np.asarray(state_sync.queue_tokens),
                                  np.asarray(state_time.queue_tokens))
    np.testing.assert_array_equal(np.asarray(state_sync.resident),
                                  np.asarray(state_time.resident))
    np.testing.assert_array_equal(np.asarray(state_sync.last_use),
                                  np.asarray(state_time.last_use))


def test_midstream_snapshot_carries_wall_clock():
    """Snapshotting the oracle mid-stream must thread time_s or the next
    batched drain would replay the whole elapsed wall clock."""
    with enable_x64():
        rng = np.random.default_rng(15)
        fleet = _random_multicell_fleet(rng, 2, 2)
        models, bits, toks, cells, arrivals = _random_stream(rng, 200, 2)

        router, sc_choice, _ = _run_scalar(
            fleet, models, bits, toks, cells, arrivals
        )

        half = 100
        warm = ModelAwareRouter(copy.deepcopy(fleet), CATALOG)
        for m, b, t, c, a in zip(models[:half], bits[:half], toks[:half],
                                 cells[:half], arrivals[:half]):
            warm.route(Request(int(m), float(b), int(t), cell=int(c),
                               arrival_s=float(a)))
        params, state = br.fleet_from_servers(
            warm.servers, CATALOG, clock=warm.clock, time_s=warm.time_s
        )
        reqs = br.RequestBatch(
            model=jnp.asarray(models[half:], jnp.int32),
            prompt_bits=jnp.asarray(bits[half:], jnp.float64),
            gen_tokens=jnp.asarray(toks[half:], jnp.float64),
            cell=jnp.asarray(cells[half:], jnp.int32),
            arrival_s=jnp.asarray(arrivals[half:], jnp.float64),
        )
        state, out = br.route_batch(params, state, reqs)
        np.testing.assert_array_equal(np.asarray(out.choice),
                                      sc_choice[half:])
        _assert_fleet_state_matches(router, state)


@pytest.mark.parametrize("backend", ["xla", "pallas-interpret"])
@pytest.mark.parametrize("seed,n_cells,chunk", [
    (40, 1, 64), (41, 2, 100), (42, 4, 64), (43, 4, 300),
])
def test_chunked_multicell_matches_scalar_oracle(seed, n_cells, chunk,
                                                 backend):
    """The chunked two-phase commit reproduces the oracle for C in
    {1, 2, 4} cells with cloud fallback + time drain enabled, under
    both scoring backends, including chunks that do not divide B."""
    with enable_x64():
        rng = np.random.default_rng(seed)
        fleet = _random_multicell_fleet(rng, n_cells, 3)
        models, bits, toks, cells, arrivals = _random_stream(
            rng, 250, n_cells
        )
        router, sc_choice, sc_lat = _run_scalar(
            fleet, models, bits, toks, cells, arrivals
        )
        params, state = br.fleet_from_servers(fleet, CATALOG)
        reqs = br.RequestBatch(
            model=jnp.asarray(models, jnp.int32),
            prompt_bits=jnp.asarray(bits, jnp.float64),
            gen_tokens=jnp.asarray(toks, jnp.float64),
            cell=jnp.asarray(cells, jnp.int32),
            arrival_s=jnp.asarray(arrivals, jnp.float64),
        )
        state, out = br.route_batch(params, state, reqs, chunk=chunk,
                                    backend=backend)
        np.testing.assert_array_equal(np.asarray(out.choice), sc_choice)
        # the chunked path re-associates eq. 9 (see batch_router
        # docstring): latencies agree to ulps, decisions exactly
        np.testing.assert_allclose(np.asarray(out.latency), sc_lat,
                                   rtol=1e-12, atol=0.0)
        _assert_fleet_state_matches(router, state)


def test_chunked_orphan_rejection_and_stats():
    """Chunked path: infeasible requests reject uncommitted, and
    ``stats`` masks them out of mean_latency via completion_rate."""
    rng = np.random.default_rng(44)
    fleet = _random_multicell_fleet(rng, 2, 2, cloud=False)
    models = np.array([0, 1, 2, 3])
    bits = np.array([2e5, 3e5, 4e5, 5e5])
    toks = np.array([8, 16, 4, 2])
    cells = np.array([0, 5, 1, 7])  # requests 1 and 3 are unroutable
    arrivals = np.array([0.1, 0.2, 0.3, 0.4])

    router, sc_choice, _ = _run_scalar(
        fleet, models, bits, toks, cells, arrivals
    )
    params, state = br.fleet_from_servers(fleet, CATALOG)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
        cell=jnp.asarray(cells, jnp.int32),
        arrival_s=jnp.asarray(arrivals, jnp.float32),
    )
    state, out = br.route_batch(params, state, reqs, chunk=3)
    np.testing.assert_array_equal(np.asarray(out.choice), sc_choice)
    assert np.isinf(np.asarray(out.latency)[[1, 3]]).all()
    _assert_fleet_state_matches(router, state)

    summary = br.stats(out)
    assert summary["completion_rate"] == pytest.approx(0.5)
    assert np.isfinite(summary["mean_latency"])


def test_chunked_clamps_custom_policy_like_legacy():
    """A custom callable policy that picks out-of-cell servers is
    clamped to the masked argmin identically on the chunked and
    single-scan paths (decision-for-decision, state-for-state)."""

    def rogue(lats, obs, queue):
        return jnp.int32(0)  # always server 0, whatever the cell

    rng = np.random.default_rng(45)
    fleet = _random_multicell_fleet(rng, 3, 2)
    models, bits, toks, cells, arrivals = _random_stream(rng, 150, 3)
    params, state = br.fleet_from_servers(fleet, CATALOG)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
        cell=jnp.asarray(cells, jnp.int32),
        arrival_s=jnp.asarray(arrivals, jnp.float32),
    )
    s0, o0 = br.route_batch(params, state, reqs, policy=rogue)
    s1, o1 = br.route_batch(params, state, reqs, policy=rogue, chunk=64)
    np.testing.assert_array_equal(np.asarray(o0.choice),
                                  np.asarray(o1.choice))
    np.testing.assert_array_equal(np.asarray(s0.resident),
                                  np.asarray(s1.resident))
    srv_cell = np.array([s.cell for s in fleet])
    chosen = srv_cell[np.asarray(o1.choice)]
    assert np.all((chosen == cells) | (chosen == CLOUD_CELL))


def test_actor_cannot_escape_cell_mask():
    """An actor that picks out-of-cell servers is clamped to the masked
    greedy argmin — identically in the scalar and batched paths."""

    def rogue_actor(obs, lats):
        return jnp.int32(0)  # always server 0, whatever the cell

    rng = np.random.default_rng(16)
    fleet = _random_multicell_fleet(rng, 3, 2)
    models, bits, toks, cells, arrivals = _random_stream(rng, 150, 3)

    router = ModelAwareRouter(copy.deepcopy(fleet), CATALOG,
                              policy="actor", actor=rogue_actor)
    sc_choice = [
        router.route(Request(int(m), float(b), int(t), cell=int(c),
                             arrival_s=float(a)))[0]
        for m, b, t, c, a in zip(models, bits, toks, cells, arrivals)
    ]

    params, state = br.fleet_from_servers(fleet, CATALOG)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
        cell=jnp.asarray(cells, jnp.int32),
        arrival_s=jnp.asarray(arrivals, jnp.float32),
    )
    state, out = br.route_batch(params, state, reqs, policy="actor",
                                actor=rogue_actor)
    np.testing.assert_array_equal(np.asarray(out.choice),
                                  np.array(sc_choice))
    srv_cell = np.array([s.cell for s in fleet])
    chosen = srv_cell[np.asarray(out.choice)]
    assert np.all((chosen == cells) | (chosen == CLOUD_CELL))
    # server 0 (cell 0) must still be honoured for cell-0 requests
    assert np.any(np.asarray(out.choice)[cells == 0] == 0)


def test_orphan_cell_requests_are_rejected_uncommitted():
    """A cell with no servers and no cloud column: choice -1, inf latency,
    and NO state mutation — identically in scalar and batched paths."""
    rng = np.random.default_rng(17)
    fleet = _random_multicell_fleet(rng, 2, 2, cloud=False)
    # cells: request 0 is routable (cell 0); request 1 references cell 5
    models = np.array([0, 1, 2])
    bits = np.array([2e5, 3e5, 4e5])
    toks = np.array([8, 16, 4])
    cells = np.array([0, 5, 1])
    arrivals = np.array([0.1, 0.2, 0.3])

    router, sc_choice, sc_lat = _run_scalar(
        fleet, models, bits, toks, cells, arrivals
    )
    state, out = _run_batched(
        fleet, models, bits, toks, cells, arrivals, jnp.float32
    )
    assert sc_choice.tolist()[1] == -1 and np.isinf(sc_lat[1])
    np.testing.assert_array_equal(np.asarray(out.choice), sc_choice)
    assert np.isinf(np.asarray(out.latency)[1])
    assert not bool(np.asarray(out.hit)[1])
    _assert_fleet_state_matches(router, state)
    # the orphan's model must not have been cached anywhere new
    initially = np.array([1 in s.resident for s in fleet])
    np.testing.assert_array_equal(np.asarray(state.resident)[:, 1], initially)


@pytest.mark.parametrize("backend", ["xla", "pallas-interpret"])
@pytest.mark.parametrize("seed,n_cells,chunk,cache_slots,cloud", [
    (50, 1, 64, 2, True),    # hit-heavy: whole chunks commit speculatively
    (51, 2, 100, 1, False),  # slots=1 + orphan cells: constant conflicts
    (52, 4, 64, 1, True),    # miss-heavy with cloud + drain
])
def test_speculative_commit_matches_scalar_oracle(seed, n_cells, chunk,
                                                  cache_slots, cloud,
                                                  backend):
    """The speculative parallel commit reproduces the scalar oracle —
    choices, LRU clocks, residency, queues and fleet clock — for C in
    {1, 2, 4} cells with cloud fallback, time drain and rejections, on
    both scoring backends. The slots=1 configs force a residency-
    mutating commit (a conflict) in essentially every chunk, so the
    serial suffix replay is exercised, not just the all-hit fast path;
    the no-cloud config streams orphan cells so rejected requests flow
    through the speculative recurrence too. The speculative path must
    also equal the plain correction scan bit for bit (latencies
    included), not merely to ulps."""
    with enable_x64():
        rng = np.random.default_rng(seed)
        fleet = _random_multicell_fleet(rng, n_cells, 3,
                                        cache_slots=cache_slots, cloud=cloud)
        # without the cloud column, draw some unroutable cells too
        models, bits, toks, cells, arrivals = _random_stream(
            rng, 250, n_cells if cloud else n_cells + 1
        )
        router, sc_choice, sc_lat = _run_scalar(
            fleet, models, bits, toks, cells, arrivals
        )
        params, state0 = br.fleet_from_servers(fleet, CATALOG)
        reqs = br.RequestBatch(
            model=jnp.asarray(models, jnp.int32),
            prompt_bits=jnp.asarray(bits, jnp.float64),
            gen_tokens=jnp.asarray(toks, jnp.float64),
            cell=jnp.asarray(cells, jnp.int32),
            arrival_s=jnp.asarray(arrivals, jnp.float64),
        )
        st_spec, out_spec = br.route_batch(params, state0, reqs, chunk=chunk,
                                           backend=backend, speculative=True)
        st_ser, out_ser = br.route_batch(params, state0, reqs, chunk=chunk,
                                         backend=backend, speculative=False)
        if not cloud:  # the orphan cells actually exercised rejection
            assert (sc_choice == -1).any()
        np.testing.assert_array_equal(np.asarray(out_spec.choice), sc_choice)
        np.testing.assert_allclose(np.asarray(out_spec.latency), sc_lat,
                                   rtol=1e-12, atol=0.0)
        _assert_fleet_state_matches(router, st_spec)
        # speculative vs serial correction scan: bit-identical
        np.testing.assert_array_equal(np.asarray(out_spec.choice),
                                      np.asarray(out_ser.choice))
        np.testing.assert_array_equal(np.asarray(out_spec.latency),
                                      np.asarray(out_ser.latency))
        np.testing.assert_array_equal(np.asarray(out_spec.hit),
                                      np.asarray(out_ser.hit))
        for a, b in zip(st_spec, st_ser):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_empty_cell_rejection_heavy_chunked_matches_scan():
    """An EMPTY cell (no servers, no cloud column): every request tagged
    to it is rejected, and the scan, chunked and speculative paths all
    agree with the scalar oracle decision for decision — the inert
    rejected steps must not desynchronise the chunk bookkeeping."""
    mk = lambda c, i, res: EdgeServer(
        name=f"c{c}-es{i}", flops_per_s=1e14, cache_slots=2,
        uplink_bps=1e8, backhaul_bps=1e9, resident=res, cell=c,
        drain_rate=2e4,
    )
    fleet = [mk(0, 0, [0, 1]), mk(0, 1, [2, 3]),
             mk(2, 0, [1, 2]), mk(2, 1, [0, 3])]  # cell 1 has no servers
    rng = np.random.default_rng(53)
    models, bits, toks, cells, arrivals = _random_stream(rng, 200, 3)
    assert (cells == 1).any()

    router, sc_choice, _ = _run_scalar(
        fleet, models, bits, toks, cells, arrivals
    )
    assert (sc_choice == -1).sum() >= 50  # genuinely rejection-heavy
    params, state0 = br.fleet_from_servers(fleet, CATALOG)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
        cell=jnp.asarray(cells, jnp.int32),
        arrival_s=jnp.asarray(arrivals, jnp.float32),
    )
    st_scan, out_scan = br.route_batch(params, state0, reqs)
    runs = {
        "chunked": br.route_batch(params, state0, reqs, chunk=64,
                                  speculative=False),
        "spec": br.route_batch(params, state0, reqs, chunk=64,
                               speculative=True),
    }
    np.testing.assert_array_equal(np.asarray(out_scan.choice), sc_choice)
    # f32 stream: decisions/residency vs the oracle exactly, queues to f32
    resident = np.asarray(st_scan.resident)
    for i, srv in enumerate(router.servers):
        assert set(np.nonzero(resident[i])[0]) == set(srv.resident), i
    np.testing.assert_allclose(
        np.asarray(st_scan.queue_tokens),
        [s.queue_tokens for s in router.servers], rtol=1e-4,
    )
    for name, (st, out) in runs.items():
        np.testing.assert_array_equal(np.asarray(out.choice), sc_choice,
                                      err_msg=name)
        np.testing.assert_array_equal(np.asarray(out.hit),
                                      np.asarray(out_scan.hit), err_msg=name)
        np.testing.assert_array_equal(np.asarray(st.resident), resident,
                                      err_msg=name)
        np.testing.assert_array_equal(np.asarray(st.last_use),
                                      np.asarray(st_scan.last_use),
                                      err_msg=name)
    # the hit-rate fix: rejected requests don't deflate the metric
    s = br.stats(out_scan)
    ok = sc_choice >= 0
    assert s["completion_rate"] == pytest.approx(ok.mean())
    assert s["residency_hit_rate"] == pytest.approx(
        np.asarray(out_scan.hit)[ok].mean())


# ---------------------------------------------------------------------------
# mesh-sharded routing (core.mesh_router) — the multi-device matrix.
#
# Marked ``multidevice``: on a 1-device host conftest re-runs these once in
# a forced-8-device child (see tests/conftest.py and docs/sharding.md).
# Exactness tiers, pinned here exactly as the module docstring states them:
#   * device-count invariance is ALWAYS bitwise (any fleet, any policy);
#   * vs the plain single-device scan, bitwise whenever no cross-cell cloud
#     feedback exists inside the window (cloud-free fleets, or streams
#     where a single cell contributes all cloud traffic) and drain_rate=0;
#   * with drain_rate > 0 the per-cell decay composition differs from the
#     per-global-arrival one by ulps — choices/latencies agree, queues to
#     a tolerance.
# ---------------------------------------------------------------------------
from repro.core import mesh_router as mr  # noqa: E402


def _sharded_state_equal(st_a, st_b):
    for f in ("resident", "queue_tokens"):
        np.testing.assert_array_equal(np.asarray(getattr(st_a, f)),
                                      np.asarray(getattr(st_b, f)), err_msg=f)
    assert int(st_a.clock) == int(st_b.clock)
    if st_a.time_s is not None:
        np.testing.assert_array_equal(np.asarray(st_a.time_s),
                                      np.asarray(st_b.time_s))
    res = np.asarray(st_a.resident)
    np.testing.assert_array_equal(np.asarray(st_a.last_use)[res],
                                  np.asarray(st_b.last_use)[res])


def _sharded_outcome_equal(out_a, out_b):
    for f in br.RouteOutcome._fields:
        np.testing.assert_array_equal(np.asarray(getattr(out_a, f)),
                                      np.asarray(getattr(out_b, f)), err_msg=f)


@pytest.mark.multidevice
@pytest.mark.parametrize("devices", [1, 2, 4, 8])
@pytest.mark.parametrize("n_cells", [2, 4, 8])
def test_sharded_bitwise_vs_plain_cloud_free(n_cells, devices):
    """C x D matrix (non-dividing pairs included: 8 cells on 4 devices
    packs 2 blocks/device, 2 cells on 8 leaves idle shards): cloud-free
    drain-free fleets are bitwise vs the plain scan AND the oracle."""
    rng = np.random.default_rng(100 + 10 * n_cells + devices)
    fleet = _random_multicell_fleet(rng, n_cells, 3, drain_hi=0.0,
                                    cloud=False)
    models, bits, toks, cells, arrivals = _random_stream(rng, 200, n_cells)
    params, state0 = br.fleet_from_servers(fleet, CATALOG)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
        cell=jnp.asarray(cells, jnp.int32),
        arrival_s=jnp.asarray(arrivals, jnp.float32),
    )
    st_p, out_p = br.route_batch(params, state0, reqs)
    st_s, out_s = mr.route_batch_sharded(params, state0, reqs,
                                         num_devices=devices)
    _sharded_outcome_equal(out_p, out_s)
    _sharded_state_equal(st_p, st_s)
    router, sc_choice, _ = _run_scalar(fleet, models, bits, toks, cells,
                                       arrivals)
    np.testing.assert_array_equal(np.asarray(out_s.choice), sc_choice)


@pytest.mark.multidevice
@pytest.mark.parametrize("n_cells,devices", [(3, 8), (5, 4), (6, 4)])
def test_sharded_non_dividing_cell_device_counts(n_cells, devices):
    """Cell counts that do not divide (or even reach) the device count
    still route bitwise vs the plain scan."""
    rng = np.random.default_rng(200 + n_cells)
    fleet = _random_multicell_fleet(rng, n_cells, 2, drain_hi=0.0,
                                    cloud=False)
    models, bits, toks, cells, arrivals = _random_stream(rng, 150, n_cells)
    params, state0 = br.fleet_from_servers(fleet, CATALOG)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
        cell=jnp.asarray(cells, jnp.int32),
        arrival_s=jnp.asarray(arrivals, jnp.float32),
    )
    st_p, out_p = br.route_batch(params, state0, reqs)
    st_s, out_s = mr.route_batch_sharded(params, state0, reqs,
                                         num_devices=devices)
    _sharded_outcome_equal(out_p, out_s)
    _sharded_state_equal(st_p, st_s)


@pytest.mark.multidevice
@pytest.mark.parametrize("devices", [2, 8])
def test_sharded_cloud_single_contributor_bitwise(devices):
    """With a cloud column but ALL traffic from one cell, no cross-cell
    cloud feedback exists — the sharded window is bitwise vs the plain
    scan, cloud backlog and cloud LRU included."""
    rng = np.random.default_rng(300 + devices)
    fleet = _random_multicell_fleet(rng, 4, 2, drain_hi=0.0, cloud=True)
    models, bits, toks, _, arrivals = _random_stream(rng, 150, 1)
    cells = np.zeros(150, np.int64)
    params, state0 = br.fleet_from_servers(fleet, CATALOG)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
        cell=jnp.asarray(cells, jnp.int32),
        arrival_s=jnp.asarray(arrivals, jnp.float32),
    )
    st_p, out_p = br.route_batch(params, state0, reqs)
    st_s, out_s = mr.route_batch_sharded(params, state0, reqs,
                                         num_devices=devices)
    # the fixture must actually exercise the shared cloud column
    srv_cell = np.array([s.cell for s in fleet])
    assert (srv_cell[np.asarray(out_s.choice)] == CLOUD_CELL).any()
    _sharded_outcome_equal(out_p, out_s)
    _sharded_state_equal(st_p, st_s)


@pytest.mark.multidevice
@pytest.mark.parametrize("chunk", [None, 64])
@pytest.mark.parametrize("n_cells", [3, 8])
def test_sharded_device_count_invariance(n_cells, chunk):
    """THE sharded-router invariant: the device count is a pure execution
    detail. Cloud on, drain on, all cells contributing — the hardest
    configuration — must produce bit-identical choices, outcomes, queues,
    residency and LRU clocks for D in {1, 2, 4, 8}."""
    rng = np.random.default_rng(400 + n_cells + (0 if chunk is None else 1))
    fleet = _random_multicell_fleet(rng, n_cells, 2, drain_hi=40.0,
                                    cloud=True)
    models, bits, toks, cells, arrivals = _random_stream(rng, 200, n_cells)
    params, state0 = br.fleet_from_servers(fleet, CATALOG)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
        cell=jnp.asarray(cells, jnp.int32),
        arrival_s=jnp.asarray(arrivals, jnp.float32),
    )
    st_1, out_1 = mr.route_batch_sharded(params, state0, reqs,
                                         num_devices=1, chunk=chunk)
    for d in (2, 4, 8):
        st_d, out_d = mr.route_batch_sharded(params, state0, reqs,
                                             num_devices=d, chunk=chunk)
        _sharded_outcome_equal(out_1, out_d)
        _sharded_state_equal(st_1, st_d)


@pytest.mark.multidevice
def test_sharded_rejections_and_orphan_cells():
    """No cloud + out-of-range request cells: rejections (-1, inf, no
    mutation) flow through the sharded path exactly like the plain scan
    and the scalar oracle."""
    rng = np.random.default_rng(500)
    fleet = _random_multicell_fleet(rng, 3, 2, drain_hi=0.0, cloud=False)
    models, bits, toks, cells, arrivals = _random_stream(rng, 150, 5)
    assert (cells >= 3).any()
    params, state0 = br.fleet_from_servers(fleet, CATALOG)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
        cell=jnp.asarray(cells, jnp.int32),
        arrival_s=jnp.asarray(arrivals, jnp.float32),
    )
    st_p, out_p = br.route_batch(params, state0, reqs)
    st_s, out_s = mr.route_batch_sharded(params, state0, reqs, num_devices=4)
    router, sc_choice, _ = _run_scalar(fleet, models, bits, toks, cells,
                                       arrivals)
    assert (sc_choice == -1).any()
    np.testing.assert_array_equal(np.asarray(out_s.choice), sc_choice)
    _sharded_outcome_equal(out_p, out_s)
    _sharded_state_equal(st_p, st_s)


@pytest.mark.multidevice
def test_sharded_drain_rate_close_to_plain():
    """drain_rate > 0: each cell composes its queue decay over its OWN
    arrival gaps while the plain scan decays at every global arrival —
    same total elapsed time, but the clamp at zero fires at different
    instants, so queues drift a fraction of a percent over a window.
    Decisions and latencies agree; queues to a tolerance. (Bitwise
    ACROSS device counts is pinned separately by
    test_sharded_device_count_invariance.)"""
    with enable_x64():
        rng = np.random.default_rng(600)
        fleet = _random_multicell_fleet(rng, 4, 3, drain_hi=40.0,
                                        cloud=False)
        models, bits, toks, cells, arrivals = _random_stream(rng, 250, 4)
        params, state0 = br.fleet_from_servers(fleet, CATALOG)
        reqs = br.RequestBatch(
            model=jnp.asarray(models, jnp.int32),
            prompt_bits=jnp.asarray(bits, jnp.float64),
            gen_tokens=jnp.asarray(toks, jnp.float64),
            cell=jnp.asarray(cells, jnp.int32),
            arrival_s=jnp.asarray(arrivals, jnp.float64),
        )
        st_p, out_p = br.route_batch(params, state0, reqs)
        st_s, out_s = mr.route_batch_sharded(params, state0, reqs,
                                             num_devices=4)
        np.testing.assert_array_equal(np.asarray(out_p.choice),
                                      np.asarray(out_s.choice))
        np.testing.assert_allclose(np.asarray(out_p.latency),
                                   np.asarray(out_s.latency),
                                   rtol=1e-12, atol=0.0)
        np.testing.assert_array_equal(np.asarray(st_p.resident),
                                      np.asarray(st_s.resident))
        np.testing.assert_allclose(np.asarray(st_p.queue_tokens),
                                   np.asarray(st_s.queue_tokens),
                                   rtol=1e-2, atol=1e-6)


@pytest.mark.multidevice
def test_sharded_chunked_and_speculative_paths_agree():
    """Inside each cell shard the scan/chunked/speculative inner paths
    stay interchangeable on 4 devices: identical decisions, residency
    and LRU clocks; latencies/queues to ulps (the chunked commit
    re-associates the eq. 9 sums exactly like the unsharded chunked
    path — see test_chunked_multicell_matches_scalar_oracle). The two
    chunked variants (speculative on/off) ARE bitwise twins."""
    rng = np.random.default_rng(700)
    fleet = _random_multicell_fleet(rng, 4, 3, drain_hi=0.0, cloud=False)
    models, bits, toks, cells, arrivals = _random_stream(rng, 200, 4)
    params, state0 = br.fleet_from_servers(fleet, CATALOG)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
        cell=jnp.asarray(cells, jnp.int32),
        arrival_s=jnp.asarray(arrivals, jnp.float32),
    )
    st_a, out_a = mr.route_batch_sharded(params, state0, reqs, num_devices=4)
    st_b, out_b = mr.route_batch_sharded(params, state0, reqs, num_devices=4,
                                         chunk=32, speculative=True)
    st_c, out_c = mr.route_batch_sharded(params, state0, reqs, num_devices=4,
                                         chunk=32, speculative=False)
    for st, out in ((st_b, out_b), (st_c, out_c)):
        np.testing.assert_array_equal(np.asarray(out_a.choice),
                                      np.asarray(out.choice))
        np.testing.assert_array_equal(np.asarray(out_a.hit),
                                      np.asarray(out.hit))
        np.testing.assert_allclose(np.asarray(out_a.latency),
                                   np.asarray(out.latency), rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(st_a.resident),
                                      np.asarray(st.resident))
        res = np.asarray(st_a.resident)
        np.testing.assert_array_equal(np.asarray(st_a.last_use)[res],
                                      np.asarray(st.last_use)[res])
        np.testing.assert_allclose(np.asarray(st_a.queue_tokens),
                                   np.asarray(st.queue_tokens), rtol=1e-5)
    _sharded_outcome_equal(out_b, out_c)
    _sharded_state_equal(st_b, st_c)
