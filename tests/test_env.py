"""Environment invariants (paper §II) under random policies."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import baselines, env as env_lib
from repro.core.types import Action


@pytest.fixture(scope="module")
def p():
    return env_lib.default_params(num_eds=6, num_models=4)


def _rollout(p, policy, steps=12, key=0):
    key = jax.random.key(key)
    state = env_lib.reset(key, p)
    outs = []
    for _ in range(steps):
        key, k = jax.random.split(key)
        obs = env_lib.observe(state, p)
        act = policy(k, obs, p)
        state, obs, out, done = env_lib.step(state, act, p)
        outs.append(out)
    return state, outs


def test_cache_capacity_invariant(p):
    state, _ = _rollout(p, baselines.random_policy, steps=30)
    per_es = state.cache.sum(axis=1)
    assert bool(jnp.all(per_es <= p.cache_slots))
    assert bool(jnp.all((state.cache == 0) | (state.cache == 1)))


def test_outcome_ranges(p):
    _, outs = _rollout(p, baselines.random_policy)
    for o in outs:
        assert bool(jnp.all(o.latency >= 0))
        assert bool(jnp.all(o.energy >= 0))
        assert bool(jnp.all((o.completed == 0) | (o.completed == 1)))
        assert bool(jnp.all(o.reward <= 0))  # reward is cost-negative


def test_local_only_never_fails_compat(p):
    local = lambda k, obs, p_: Action(
        target=jnp.zeros((p_.num_eds,), jnp.int32),
        eta=jnp.zeros((p_.num_eds,)),
        beta=jnp.zeros((p_.num_eds,)),
    )
    _, outs = _rollout(p, local)
    for o in outs:
        assert bool(jnp.all(o.failed_compat == 0))
        assert bool(jnp.all(o.switch_latency == 0))


def test_download_updates_cache(p):
    """Forcing downloads to one ES eventually caches the requested models."""
    def policy(k, obs, p_):
        return Action(
            target=jnp.ones((p_.num_eds,), jnp.int32),  # all to ES 0
            eta=jnp.full((p_.num_eds,), 0.5),
            beta=jnp.ones((p_.num_eds,)),
        )

    state, outs = _rollout(p, policy, steps=20)
    # some downloads must have happened (switch latency observed)
    assert any(float(o.switch_latency.sum()) > 0 for o in outs)
    assert float(state.cache[0].sum()) == p.cache_slots  # ES 0 full (LRU)


def test_deadline_violation_marks_incomplete(p):
    """eta=0 on huge local tasks -> slow EDs must miss the deadline."""
    import dataclasses
    slow = p._replace(task_mb_lo=20.0, task_mb_hi=20.0, rho_lo=100.0,
                      rho_hi=100.0, f_ed_lo=1e9, f_ed_hi=1e9)
    local = lambda k, obs, p_: Action(
        target=jnp.zeros((p_.num_eds,), jnp.int32),
        eta=jnp.zeros((p_.num_eds,)),
        beta=jnp.zeros((p_.num_eds,)),
    )
    _, outs = _rollout(slow, local)
    comp = jnp.stack([o.completed for o in outs])
    assert float(comp.mean()) < 0.1  # 1.6e10 cycles at 1 GHz >> 5 s deadline


def test_observation_layout(p):
    state = env_lib.reset(jax.random.key(0), p)
    obs = env_lib.observe(state, p)
    assert obs.shape == (p.num_eds, env_lib.obs_dim(p))
    # compat slice must mirror cache rows for each agent's needed model
    sl = baselines._obs_slices(p)
    compat = obs[:, sl["compat"][0]:sl["compat"][1]]
    need = state.task.mu
    expected = state.cache[:, need].T
    assert bool(jnp.all(compat == expected))


def test_lru_keep_retains_most_recent(p):
    """Direct unit test: ``lru_keep`` keeps exactly ``slots`` most-recent."""
    cache = jnp.array([1.0, 1.0, 1.0, 0.0, 1.0])
    last = jnp.array([3, 9, 1, 99, 7], jnp.int32)  # 99 not cached: ignored
    kept = env_lib.lru_keep(cache, last, 2)
    assert kept.tolist() == [0.0, 1.0, 0.0, 0.0, 1.0]  # clocks 9 and 7 stay
    # under capacity: nothing evicted
    kept3 = env_lib.lru_keep(jnp.array([0.0, 1.0, 0.0, 0.0, 1.0]), last, 3)
    assert kept3.tolist() == [0.0, 1.0, 0.0, 0.0, 1.0]
    # slots == cached count: identity
    assert env_lib.lru_keep(cache, last, 4).tolist() == cache.tolist()


def test_lru_eviction_sequence_in_env(p):
    """Env-level: forcing model downloads to one ES evicts least-recent."""
    pp = env_lib.default_params(num_eds=1, num_models=4, num_ess=2)
    state = env_lib.reset(jax.random.key(0), pp)
    # route the single ED's task through ES 0 with forced downloads
    force = lambda: Action(target=jnp.array([1], jnp.int32),
                           eta=jnp.array([0.8]), beta=jnp.array([1.0]))

    def set_task(state, mu):
        task = state.task._replace(mu=jnp.array([mu], jnp.int32))
        return state._replace(task=task)

    seq = [0, 1, 2, 3, 0]
    for mu in seq:
        state = set_task(state, mu)
        state, _, _, _ = env_lib.step(state, force(), pp)
    # cache_slots=2: after 0,1,2,3,0 the two most recent are {3, 0}
    assert set(jnp.nonzero(state.cache[0])[0].tolist()) == {3, 0}
    assert float(state.cache[0].sum()) == pp.cache_slots


def test_fifo_load_counts_per_chosen_es(p):
    """Direct unit test: contention divisor = head-count at the chosen ES."""
    es_idx = jnp.array([0, 0, 1, 2, 2, 2], jnp.int32)
    offloaded = jnp.array([True, True, True, True, True, False])
    load = env_lib.fifo_load(es_idx, offloaded, 3)
    # ES0 gets 2 agents, ES1 one, ES2 two offloaders (+1 local, not counted)
    assert load.tolist() == [2.0, 2.0, 1.0, 2.0, 2.0, 2.0]
    # non-offloaders never divide by zero
    none = env_lib.fifo_load(es_idx, jnp.zeros((6,), bool), 3)
    assert none.tolist() == [1.0] * 6


def test_fifo_load_splits_rate_and_cycles(p):
    """load_m scales both the uplink share and the ES cycle share (eq. 9):
    doubling the crowd on one ES doubles per-agent compute latency."""
    pp = env_lib.default_params(num_eds=4, num_models=2, num_ess=2)
    state = env_lib.reset(jax.random.key(1), pp)
    # all tasks identical so shares are directly comparable
    task = state.task._replace(
        mu=jnp.zeros((4,), jnp.int32),
        x_bits=jnp.full((4,), 8e6),
        rho=jnp.full((4,), 50.0),
    )
    state = state._replace(task=task)
    pair = Action(target=jnp.array([1, 1, 2, 2], jnp.int32),
                  eta=jnp.ones((4,)), beta=jnp.ones((4,)))
    solo = Action(target=jnp.array([1, 2, 2, 2], jnp.int32),
                  eta=jnp.ones((4,)), beta=jnp.ones((4,)))
    _, _, out_pair, _ = env_lib.step(state, pair, pp)
    _, _, out_solo, _ = env_lib.step(state, solo, pp)
    # agent 0: alone on ES0 in `solo` (load 1) vs paired (load 2)
    lat_paired = float(out_pair.latency[0])
    lat_alone = float(out_solo.latency[0])
    assert lat_paired > lat_alone


def test_contention_raises_latency(p):
    """All agents on one ES must be slower than spreading across ESs."""
    key = jax.random.key(3)
    state = env_lib.reset(key, p)
    m = p.num_eds
    crowd = Action(target=jnp.ones((m,), jnp.int32),
                   eta=jnp.ones((m,)), beta=jnp.ones((m,)))
    spread = Action(target=(jnp.arange(m) % p.num_ess + 1).astype(jnp.int32),
                    eta=jnp.ones((m,)), beta=jnp.ones((m,)))
    _, _, out_crowd, _ = env_lib.step(state, crowd, p)
    _, _, out_spread, _ = env_lib.step(state, spread, p)
    assert float(out_crowd.latency.mean()) > float(out_spread.latency.mean())


def test_cross_cell_offload_is_infeasible():
    """num_cells=2: offloading to an out-of-cell ES counts as failed."""
    pp = env_lib.default_params(num_eds=4, num_models=2, num_ess=4,
                                num_cells=2)
    # round-robin: ED cells [0,1,0,1], ES cells [0,1,0,1]; all target ES 1
    state = env_lib.reset(jax.random.key(2), pp)
    act = Action(target=jnp.full((4,), 2, jnp.int32),  # ES index 1 (cell 1)
                 eta=jnp.ones((4,)), beta=jnp.ones((4,)))
    _, _, out, _ = env_lib.step(state, act, pp)
    assert out.failed_compat.tolist() == [1.0, 0.0, 1.0, 0.0]
    assert out.completed.tolist()[0] == 0.0 and out.completed.tolist()[2] == 0.0
    # cross-cell attempts must not download into the foreign ES's cache
    assert out.switch_latency[0] == 0.0 and out.switch_latency[2] == 0.0


def test_single_cell_default_keeps_paper_setting(p):
    """num_cells=1 (default): cell masks are all-visible no-ops."""
    assert p.num_cells == 1
    assert env_lib.es_cell(p).tolist() == [0] * p.num_ess
    assert env_lib.ed_cell(p).tolist() == [0] * p.num_eds
    state, outs = _rollout(p, baselines.random_policy, steps=8)
    explicit = env_lib.default_params(num_eds=6, num_models=4, num_cells=1)
    state2, outs2 = _rollout(explicit, baselines.random_policy, steps=8)
    for a, b in zip(outs, outs2):
        assert bool(jnp.all(a.reward == b.reward))
        assert bool(jnp.all(a.latency == b.latency))


def test_observe_masks_out_of_cell_compat():
    """The compat slice only shows residency of in-cell servers."""
    pp = env_lib.default_params(num_eds=4, num_models=3, num_ess=4,
                                num_cells=2)
    state = env_lib.reset(jax.random.key(4), pp)
    obs = env_lib.observe(state, pp)
    sl = baselines._obs_slices(pp)
    compat = obs[:, sl["compat"][0]:sl["compat"][1]]  # (M, N)
    in_cell = env_lib.es_cell(pp)[None, :] == env_lib.ed_cell(pp)[:, None]
    assert bool(jnp.all(jnp.where(in_cell, True, compat == 0.0)))
    full = state.cache[:, state.task.mu].T
    assert bool(jnp.all(jnp.where(in_cell, compat == full, True)))


def test_num_cells_exceeding_servers_rejected():
    """Cells with EDs but no ES are a silent-degeneracy trap: refused."""
    with pytest.raises(ValueError, match="num_cells"):
        env_lib.default_params(num_eds=8, num_models=2, num_ess=3,
                               num_cells=4)
