"""Overload economy + fault injection (docs/robustness.md).

SLO admission control (``deadline_s`` / CAUSE_ADMISSION), neighbour-cell
spill (the (C, C) adjacency at a backhaul surcharge), server outages
(``outage`` masks / ``EdgeServer.outaged`` / CAUSE_OUTAGE) and the
``FaultSpec`` fault schedules through ``workloads.simulate`` — at the
scalar-oracle, batched and episode levels, including the acceptance
bound: under ``flash-crowd-outage`` the SLO keeps the peak edge queue
p90 within 5x of steady state. Cross-path equivalence of the same knobs
is fuzzed in ``fuzz_paths.py`` / ``test_properties.py``.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import batch_router as br
from repro.core.catalog import build_catalog
from repro.core.router import (
    CAUSE_ADMISSION, CAUSE_COMPLETED, CAUSE_INFEASIBLE, CAUSE_OUTAGE,
    EdgeServer, ModelAwareRouter, Request,
)
from repro.workloads import (FaultSpec, compile_scenario, get_scenario,
                             list_scenarios, simulate)
from repro.workloads import generators as gen

CATALOG = build_catalog(
    ["smollm_135m", "starcoder2_3b", "mamba2_2p7b", "musicgen_medium"]
)


def _server(name="es0", cell=0, resident=(0, 1), drain_rate=0.0):
    return EdgeServer(name=name, flops_per_s=1e14, cache_slots=2,
                      uplink_bps=1e8, backhaul_bps=1e9,
                      resident=list(resident), cell=cell,
                      drain_rate=drain_rate)


# ---------------------------------------------------------------------------
# scalar oracle
# ---------------------------------------------------------------------------
def test_oracle_admission_rejects_and_leaves_fleet_untouched():
    r = ModelAwareRouter([_server(), _server("es1")], CATALOG)
    # model 2 is nowhere resident: the eq. 7 switch makes any deadline
    # in the microsecond range unmeetable
    choice, lat = r.route(Request(2, 1e6, 16, cell=0, deadline_s=1e-6))
    assert choice == -1 and np.isinf(lat)
    assert r.last_cause == CAUSE_ADMISSION
    for s in r.servers:  # a rejection must not commit anything
        assert s.queue_tokens == 0.0
        assert s.resident == [0, 1]
    # the same request with no SLO (or a loose one) routes fine
    choice, lat = r.route(Request(2, 1e6, 16, cell=0))
    assert choice >= 0 and np.isfinite(lat)
    assert r.last_cause == CAUSE_COMPLETED


def test_oracle_outage_masks_column_and_freezes_queue():
    fleet = [_server(drain_rate=1e3), _server("es1", drain_rate=1e3)]
    fleet[0].outaged = True
    fleet[0].queue_tokens = 100.0
    r = ModelAwareRouter(fleet, CATALOG)
    choice, _ = r.route(Request(0, 1e5, 8, cell=0, arrival_s=1.0))
    assert choice == 1                       # outaged column never wins
    assert fleet[0].queue_tokens == 100.0    # frozen, not drained
    assert fleet[1].queue_tokens > 0.0       # the survivor committed
    fleet[1].outaged = True
    choice, lat = r.route(Request(0, 1e5, 8, cell=0, arrival_s=1.1))
    assert choice == -1 and r.last_cause == CAUSE_OUTAGE
    # an empty cell is INFEASIBLE, not an outage
    r.route(Request(0, 1e5, 8, cell=7, arrival_s=1.2))
    assert r.last_cause == CAUSE_INFEASIBLE


def test_oracle_spill_visibility_and_surcharge():
    adj = np.zeros((2, 2), bool)
    adj[0, 1] = True  # one-way: cell 0 may spill into cell 1
    r = ModelAwareRouter([_server("c0", cell=0), _server("c1", cell=1)],
                         CATALOG, spill=adj)
    req = Request(0, 1e6, 8, cell=0)
    assert r._visible(r.servers[1], req)
    assert not r._visible(r.servers[0], Request(0, 1e6, 8, cell=1))
    # identical hardware: the spilled candidate costs exactly the home
    # price plus the prompt's trip over the inter-cell backhaul
    home = r._candidate_latency(r.servers[0], req)
    spilled = r._candidate_latency(r.servers[1], req)
    np.testing.assert_allclose(spilled, home + 1e6 / 1e9, rtol=1e-6)


# ---------------------------------------------------------------------------
# batched paths
# ---------------------------------------------------------------------------
def _batch(n=24, seed=0, deadline_s=None):
    rng = np.random.default_rng(seed)
    return br.RequestBatch(
        model=jnp.asarray(rng.integers(0, len(CATALOG), n), jnp.int32),
        prompt_bits=jnp.asarray(rng.uniform(1e5, 1e6, n), jnp.float32),
        gen_tokens=jnp.asarray(rng.integers(1, 64, n), jnp.float32),
        deadline_s=(None if deadline_s is None
                    else jnp.asarray(deadline_s, jnp.float32)),
    )


def test_batch_deadline_absent_and_inf_are_equivalent():
    params, state0 = br.fleet_from_servers(
        [_server(), _server("es1", resident=(2, 3))], CATALOG)
    st_none, out_none = br.route_batch(params, state0, _batch())
    st_inf, out_inf = br.route_batch(
        params, state0, _batch(deadline_s=np.full(24, np.inf)))
    np.testing.assert_array_equal(np.asarray(out_none.choice),
                                  np.asarray(out_inf.choice))
    np.testing.assert_array_equal(np.asarray(out_none.cause),
                                  np.asarray(out_inf.cause))
    assert (np.asarray(out_none.cause) == CAUSE_COMPLETED).all()
    np.testing.assert_array_equal(np.asarray(st_none.queue_tokens),
                                  np.asarray(st_inf.queue_tokens))


def test_batch_zero_deadline_rejects_everything_as_admission():
    params, state0 = br.fleet_from_servers([_server()], CATALOG)
    st, out = br.route_batch(params, state0,
                             _batch(deadline_s=np.zeros(24)))
    assert (np.asarray(out.choice) == -1).all()
    assert (np.asarray(out.cause) == CAUSE_ADMISSION).all()
    # nothing committed: the queue is untouched
    np.testing.assert_array_equal(np.asarray(st.queue_tokens),
                                  np.asarray(state0.queue_tokens))
    s = br.stats(out)
    assert s["completion_rate"] == 0.0 and s["admission_rate"] == 1.0


def test_stats_per_cause_rates_sum_to_one():
    params, state0 = br.fleet_from_servers([_server()], CATALOG)
    dl = np.where(np.arange(24) % 3 == 0, 1e-6, np.inf)
    _, out = br.route_batch(params, state0, _batch(deadline_s=dl))
    s = br.stats(out)
    total = (s["completion_rate"] + s["infeasible_rate"]
             + s["admission_rate"] + s["outage_rate"])
    assert total == pytest.approx(1.0)
    assert 0.0 < s["admission_rate"] < 1.0


def test_stats_download_rate_complements_hit_rate():
    """``download_rate`` is the completed-denominator complement of
    ``residency_hit_rate`` — the identity survives alongside the
    per-cause channels (which use the full-batch denominator), and
    under blanket ``beta = False`` refusal it is structurally 0: a
    committed refusal is necessarily a residency hit."""
    params, state0 = br.fleet_from_servers(
        [_server(resident=(0,), drain_rate=0.0)], CATALOG)
    dl = np.where(np.arange(24) % 3 == 0, 1e-6, np.inf)
    _, out = br.route_batch(params, state0, _batch(deadline_s=dl))
    s = br.stats(out)
    assert 0.0 < s["completion_rate"] < 1.0         # mixed outcome batch
    assert s["residency_hit_rate"] + s["download_rate"] \
        == pytest.approx(1.0)
    assert s["download_rate"] > 0.0                 # misses did download
    # the cause channels still close over the OTHER denominator
    assert (s["completion_rate"] + s["infeasible_rate"]
            + s["admission_rate"] + s["outage_rate"]) == pytest.approx(1.0)
    # per-window view agrees with the whole-batch identity
    ws = br.window_stats(out, np.arange(24) // 12, 2)
    done = ws["completion_rate"] > 0
    np.testing.assert_allclose(
        (ws["residency_hit_rate"] + ws["download_rate"])[done], 1.0)
    # blanket refusal: every completed request is a hit, downloads are 0
    _, ref = br.route_batch(params, state0,
                            _batch()._replace(beta=jnp.zeros(24, bool)))
    sr = br.stats(ref)
    assert sr["completion_rate"] > 0.0
    assert sr["residency_hit_rate"] == 1.0
    assert sr["download_rate"] == 0.0


def test_batch_outage_mask_excludes_server():
    params, state0 = br.fleet_from_servers(
        [_server(), _server("es1")], CATALOG)
    outage = jnp.asarray(np.array([True, False]))
    _, out = br.route_batch(params, state0, _batch(), outage=outage)
    assert (np.asarray(out.choice) == 1).all()
    _, out = br.route_batch(params, state0, _batch(),
                            outage=jnp.asarray(np.array([True, True])))
    assert (np.asarray(out.cause) == CAUSE_OUTAGE).all()


# ---------------------------------------------------------------------------
# FaultSpec + simulate
# ---------------------------------------------------------------------------
def _stream(n=64, span_s=0.1, seed=1):
    rng = np.random.default_rng(seed)
    return br.RequestBatch(
        model=jnp.asarray(rng.integers(0, len(CATALOG), n), jnp.int32),
        prompt_bits=jnp.asarray(rng.uniform(1e5, 1e6, n), jnp.float32),
        gen_tokens=jnp.asarray(rng.integers(8, 64, n), jnp.float32),
        arrival_s=jnp.asarray(np.linspace(0.0, span_s, n), jnp.float32),
    )


def test_faultspec_validation():
    params, state0 = br.fleet_from_servers(
        [_server(drain_rate=1e3), _server("es1", drain_rate=1e3)], CATALOG)
    with pytest.raises(ValueError, match="2 servers"):
        simulate(params, state0, _stream(),
                 faults=FaultSpec(outages=((5, 0.0, 1.0),)))
    no_clock = _stream()._replace(arrival_s=None)
    with pytest.raises(ValueError, match="arrival"):
        simulate(params, state0, no_clock,
                 faults=FaultSpec(outages=((0, 0.0, 1.0),)))
    with pytest.raises(ValueError, match="drain"):
        simulate(params._replace(drain_rate=None), state0, _stream(),
                 faults=FaultSpec(drain_outages=((0, 0.0, 1.0),)))
    # an empty FaultSpec is a no-op, not an error
    simulate(params, state0, _stream(), faults=FaultSpec(),
             window_requests=32)


def test_simulate_outage_windows_mask_the_down_server():
    params, state0 = br.fleet_from_servers(
        [_server(), _server("es1")], CATALOG)
    reqs = _stream(n=64, span_s=1.0)
    faults = FaultSpec(outages=((0, 0.5, 2.0),))
    _, out, series = simulate(params, state0, reqs, window_requests=16,
                              faults=faults)
    choice = np.asarray(out.choice)
    arr = np.asarray(reqs.arrival_s)
    # windows are masked by their FIRST arrival: every window starting
    # inside the fault window routes around server 0 entirely
    win_start = arr[::16]
    for w, t0 in enumerate(win_start):
        picks = choice[16 * w:16 * (w + 1)]
        if t0 >= 0.5:
            assert (picks == 1).all()
    assert (choice[:16] == 0).any()       # before the fault: 0 still wins
    assert (series.completion_rate == 1.0).all()  # the survivor absorbs all


def test_simulate_drain_outage_stalls_backlog():
    fleet = [_server(drain_rate=1e3), _server("es1", drain_rate=1e3)]
    params, state0 = br.fleet_from_servers(fleet, CATALOG)
    reqs = _stream(n=64, span_s=0.1)
    st_ok, out_ok, _ = simulate(params, state0, reqs, window_requests=16)
    st_stall, out_stall, _ = simulate(
        params, state0, reqs, window_requests=16,
        faults=FaultSpec(drain_outages=((0, 0.0, 1.0), (1, 0.0, 1.0))))
    # a drain stall never rejects — the backlog just stops moving
    assert (np.asarray(out_stall.choice) >= 0).all()
    assert (np.asarray(st_stall.queue_tokens).sum()
            > np.asarray(st_ok.queue_tokens).sum())


# ---------------------------------------------------------------------------
# scenario registry + generators
# ---------------------------------------------------------------------------
def test_degraded_family_registered():
    names = set(list_scenarios())
    assert {"slo-mix", "flash-crowd-outage", "drain-outage"} <= names
    fco = get_scenario("flash-crowd-outage")
    assert fco.faults.outages and fco.deadline_mix
    assert not get_scenario("drain-outage").deadline_mix


def test_slo_mix_stream_is_prefix_stable_with_steady():
    """The deadline rng child is LAST in the spawn order: adding the SLO
    column must not reshuffle any pre-existing column of the stream."""
    steady = compile_scenario(get_scenario("steady"), seed=3,
                              num_models=6, num_cells=2)
    slo = compile_scenario(get_scenario("slo-mix"), seed=3,
                           num_models=6, num_cells=2)
    np.testing.assert_array_equal(np.asarray(steady.model),
                                  np.asarray(slo.model))
    np.testing.assert_array_equal(np.asarray(steady.prompt_bits),
                                  np.asarray(slo.prompt_bits))
    assert steady.deadline_s is None
    dl = np.asarray(slo.deadline_s)
    assert set(np.unique(dl)) <= {np.float32(0.1), np.float32(1.0),
                                  np.float32(np.inf)}


def test_sample_deadlines_empty_mix_is_none():
    rng = np.random.default_rng(0)
    assert gen.sample_deadlines(rng, 10, ()) is None
    dl = gen.sample_deadlines(rng, 1000, ((0.5, 1.0),))
    assert (dl == 0.5).all()


# ---------------------------------------------------------------------------
# serve.py CLI validation
# ---------------------------------------------------------------------------
def test_serve_actor_flag_friendly_errors(tmp_path):
    from repro.launch.serve import resolve_policy_flag

    with pytest.raises(SystemExit, match="no actor checkpoint"):
        resolve_policy_flag(f"actor:{tmp_path / 'missing'}", None)
    corrupt = tmp_path / "ckpt" / "step_0"
    corrupt.mkdir(parents=True)
    (corrupt / "manifest.json").write_text("{not json")
    with pytest.raises(SystemExit, match="could not restore"):
        resolve_policy_flag(f"actor:{tmp_path / 'ckpt'}", None)
    with pytest.raises(SystemExit, match="needs a checkpoint directory"):
        resolve_policy_flag("actor:", None)
    assert resolve_policy_flag("greedy", None) == "greedy"


def test_serve_mesh_flag_validated_against_devices():
    from repro.launch.serve import validate_mesh_flag

    validate_mesh_flag(None)
    validate_mesh_flag(1)
    with pytest.raises(SystemExit, match="local devices"):
        validate_mesh_flag(10**6)
    with pytest.raises(SystemExit):
        validate_mesh_flag(0)


# ---------------------------------------------------------------------------
# the acceptance bound (the overload-economy headline)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_flash_crowd_outage_slo_bounds_queue_p90():
    """Under the 20x spike + whole-cell outage, SLO admission keeps the
    peak edge queue p90 within 5x of steady state — and the no-SLO
    control on the same stream shows the blow-up it prevents. Mirrors
    ``benchmarks/degraded_suite.py`` (same fleet template)."""
    from repro.launch.serve import make_multicell_fleet

    archs = ["smollm_135m", "starcoder2_3b", "mamba2_2p7b",
             "musicgen_medium", "zamba2_7b", "qwen3_32b"]
    catalog = build_catalog(archs)
    fleet = make_multicell_fleet(2, 2, catalog, slots=2, drain_rate=3e4,
                                 cloud=False)
    params, state0 = br.fleet_from_servers(fleet, catalog)

    def episode(spec):
        reqs = compile_scenario(spec, seed=0, num_models=len(archs),
                                num_cells=2)
        return simulate(params, state0, reqs, window_requests=256,
                        faults=spec.faults)

    _, _, steady = episode(get_scenario("steady"))
    bound = 5.0 * float(steady.queue_p90[-1])

    spec = get_scenario("flash-crowd-outage")
    _, out, series = episode(spec)
    cause = np.asarray(out.cause)
    assert (cause == CAUSE_ADMISSION).any()
    assert (cause == CAUSE_OUTAGE).any()
    assert float(series.queue_p90.max()) <= bound

    _, _, control = episode(spec._replace(deadline_mix=()))
    assert float(control.queue_p90.max()) > bound
