"""§Perf knobs must preserve numerics: int8 KV decode, group MoE."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import lm, moe


def test_int8_kv_decode_close_to_teacher_forced():
    cfg = dataclasses.replace(reduced(get_arch("smollm_135m")),
                              kv_cache_dtype="int8")
    params = lm.init_params(jax.random.key(0), cfg)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    logits_tf, _ = lm.forward(params, toks, cfg)
    cache = lm.init_cache(cfg, B, S)
    assert cache["k"].dtype == jnp.int8
    outs = []
    for i in range(S):
        _, logits, cache = lm.decode_step(params, cache, toks[:, i:i+1],
                                          jnp.int32(i), cfg)
        outs.append(logits[:, 0])
    err = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - logits_tf)))
    rel = err / float(jnp.max(jnp.abs(logits_tf)))
    assert rel < 0.05  # int8 quantisation bound


def test_group_moe_matches_scan_moe():
    cfg = dataclasses.replace(reduced(get_arch("qwen3_moe_235b_a22b")),
                              compute_dtype="float32", param_dtype="float32")
    params = moe.moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    y_scan, aux_s = moe.moe_apply_local(params, x, cfg, impl="scan",
                                        capacity_factor=4.0)
    y_grp, aux_g = moe.moe_apply_local(params, x, cfg, impl="group",
                                       capacity_factor=4.0)
    np.testing.assert_allclose(y_scan, y_grp, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(aux_s), float(aux_g), rtol=1e-6)


def test_ep_moe_matches_local_reference():
    """Expert-parallel shard_map path (1-shard mesh: E_loc == E) must equal
    the local TP reference exactly."""
    import jax

    cfg = dataclasses.replace(reduced(get_arch("mixtral_8x7b")),
                              compute_dtype="float32", param_dtype="float32",
                              moe_parallel="ep", moe_impl="scan")
    params = moe.moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y_ref, _ = moe.moe_apply_local(params, x, cfg, impl="scan",
                                   capacity_factor=4.0)
    from repro.distributed import sharding

    mesh = sharding.make_mesh((1, 1), ("data", "model"))
    y_ep, _ = jax.jit(lambda p, xx: moe.moe_apply(p, xx, cfg, mesh=mesh))(
        params, x
    )
    np.testing.assert_allclose(y_ep, y_ref, atol=1e-5, rtol=1e-5)


def test_group_moe_end_to_end_train_step():
    cfg = dataclasses.replace(reduced(get_arch("mixtral_8x7b")),
                              moe_impl="group")
    from repro.models.train import make_train_step

    params = lm.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    opt_init, step = make_train_step(cfg)
    _, _, m = jax.jit(step)(params, opt_init(params), {"tokens": toks,
                                                       "labels": toks})
    assert bool(jnp.isfinite(m["loss"]))
