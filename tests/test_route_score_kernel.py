"""Fused routing-score kernel vs the XLA reference — allclose, all modes.

``kernels/route_score.py`` (Pallas, interpret mode on CPU) must agree
with ``kernels/ref.route_score_xla`` — whose arithmetic is
``core.costs.edge_score_matrix`` — across dtypes (f32/bf16),
non-tile-multiple (B, N, K) shapes, cell masks on/off, and the
switch-free / queue-free base variants the chunked router uses. The
``+inf`` cell masking must match the reference exactly (same masked
set), and ``score_matrix``'s backend dispatch must expose the same
contraction through ``FleetParams``/``FleetState``.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import batch_router as br
from repro.core.catalog import build_catalog
from repro.core.router import CLOUD_CELL
from repro.kernels import ops, ref
from repro.kernels.route_score import route_score

CATALOG = build_catalog(
    ["smollm_135m", "starcoder2_3b", "mamba2_2p7b", "musicgen_medium"]
)


def _random_case(rng, b, n, k, dtype, cells=None):
    """Plain-array inputs in physically plausible ranges."""
    args = dict(
        prompt_bits=jnp.asarray(rng.uniform(1e5, 1e6, b), dtype),
        size_bits=jnp.asarray(rng.uniform(1e9, 1e10, b), dtype),
        flops_tok=jnp.asarray(rng.uniform(1e9, 1e10, b), dtype),
        work=jnp.asarray(rng.uniform(1e10, 1e12, b), dtype),
        uplink_bps=jnp.asarray(rng.uniform(5e7, 2e8, n), dtype),
        backhaul_bps=jnp.asarray(rng.uniform(5e8, 2e9, n), dtype),
        flops_per_s=jnp.asarray(rng.uniform(5e13, 2e14, n), dtype),
        queue_tokens=jnp.asarray(rng.uniform(0, 500, n), dtype),
        resident=jnp.asarray(rng.random((n, k)) < 0.5),
        model=jnp.asarray(rng.integers(0, k, b), jnp.int32),
    )
    if cells is not None:
        args["req_cell"] = jnp.asarray(rng.integers(0, cells, b), jnp.int32)
        srv = rng.integers(0, cells, n)
        srv[rng.random(n) < 0.2] = CLOUD_CELL  # sprinkle cloud columns
        args["srv_cell"] = jnp.asarray(srv, jnp.int32)
    return args


TOLS = {jnp.float32: dict(rtol=1e-6, atol=0.0),
        jnp.bfloat16: dict(rtol=2e-2, atol=0.0)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,n,k", [
    (5, 3, 4),          # everything below one tile
    (130, 65, 5),       # just past the tile boundary on both axes
    (128, 128, 4),      # exact tile multiples
    (257, 17, 9),       # ragged everywhere, K > catalogue
])
def test_kernel_matches_xla_reference(dtype, b, n, k):
    rng = np.random.default_rng(b * 1000 + n)
    args = _random_case(rng, b, n, k, dtype)
    expect = np.asarray(ref.route_score_xla(**args), np.float32)
    got = np.asarray(route_score(**args, interpret=True), np.float32)
    assert got.shape == (b, n)
    np.testing.assert_allclose(got, expect, **TOLS[dtype])


@pytest.mark.parametrize("b,n,k,cells", [(37, 9, 4, 3), (130, 33, 6, 5)])
def test_kernel_cell_mask_inf_exact(b, n, k, cells):
    """+inf lands on exactly the out-of-cell, non-cloud pairs."""
    rng = np.random.default_rng(7)
    args = _random_case(rng, b, n, k, jnp.float32, cells=cells)
    expect = np.asarray(ref.route_score_xla(**args))
    got = np.asarray(route_score(**args, interpret=True))
    np.testing.assert_array_equal(np.isinf(got), np.isinf(expect))
    visible = np.isfinite(expect)
    srv = np.asarray(args["srv_cell"]); req = np.asarray(args["req_cell"])
    assert ((srv[None, :] == req[:, None]) | (srv[None, :] == CLOUD_CELL)
            ).sum() == visible.sum()
    np.testing.assert_allclose(got[visible], expect[visible], rtol=1e-6)


@pytest.mark.parametrize("b,n,k,cells", [(41, 11, 4, 3), (130, 33, 5, 4)])
def test_kernel_spill_adjacency_matches_reference(b, n, k, cells):
    """Neighbour-cell spill: the (C, C) adjacency opens exactly the
    spilled pairs, priced at the no-mask score plus the backhaul
    surcharge, identically in the kernel and the XLA reference."""
    rng = np.random.default_rng(23)
    args = _random_case(rng, b, n, k, jnp.float32, cells=cells)
    adj = rng.random((cells, cells)) < 0.5
    np.fill_diagonal(adj, False)
    args["spill"] = jnp.asarray(adj)
    expect = np.asarray(ref.route_score_xla(**args))
    got = np.asarray(route_score(**args, interpret=True))
    np.testing.assert_array_equal(np.isinf(got), np.isinf(expect))
    fin = np.isfinite(expect)
    np.testing.assert_allclose(got[fin], expect[fin], rtol=1e-6)
    # the adjacency strictly widens the no-spill visibility...
    no_spill = np.asarray(
        ref.route_score_xla(**{**args, "spill": None}))
    widened = fin & ~np.isfinite(no_spill)
    assert widened.any()
    # ...and every widened pair pays prompt_bits/backhaul on top of the
    # unmasked eq. 11 score
    unmasked = np.asarray(ref.route_score_xla(
        **{**args, "spill": None, "req_cell": None, "srv_cell": None}))
    surcharge = (np.asarray(args["prompt_bits"])[:, None]
                 / np.asarray(args["backhaul_bps"])[None, :])
    np.testing.assert_allclose(expect[widened],
                               (unmasked + surcharge)[widened], rtol=1e-6)


def test_kernel_switch_free_and_queue_free_base():
    """The chunked router's phase-1 variants: size_bits=None drops
    eq. 7 entirely, queue_tokens=None the backlog term."""
    rng = np.random.default_rng(11)
    args = _random_case(rng, 33, 9, 4, jnp.float32)
    for drop in (("size_bits",), ("queue_tokens",),
                 ("size_bits", "queue_tokens", "resident", "model")):
        case = {**args, **{key: None for key in drop}}
        expect = np.asarray(ref.route_score_xla(**case))
        got = np.asarray(route_score(**case, interpret=True))
        np.testing.assert_allclose(got, expect, rtol=1e-6, err_msg=str(drop))


def test_ungated_when_resident_absent():
    """resident=None prices every pair at the full switch cost."""
    rng = np.random.default_rng(13)
    args = _random_case(rng, 16, 5, 4, jnp.float32)
    gated = np.asarray(route_score(**args, interpret=True))
    args["resident"] = None
    ungated = np.asarray(route_score(**args, interpret=True))
    assert (ungated >= gated - 1e-6).all()
    assert (ungated > gated).any()  # some pair actually was resident


@pytest.mark.parametrize("b,n,k", [(5, 3, 4), (130, 33, 5)])
def test_kernel_eta_scales_base(b, n, k):
    """eq. 16 eta scales the eq. 5/9 terms in kernel and reference
    alike; eta of ones is BITWISE the knob-absent call (the pre-scale
    multiplies by 1.0 — an IEEE identity)."""
    rng = np.random.default_rng(b + n)
    args = _random_case(rng, b, n, k, jnp.float32)
    eta = jnp.asarray(
        rng.choice([0.0, 0.25, 0.5, 0.75, 1.0], size=b), jnp.float32)
    expect = np.asarray(ref.route_score_xla(**args, eta=eta))
    got = np.asarray(route_score(**args, eta=eta, interpret=True))
    np.testing.assert_allclose(got, expect, rtol=1e-6)
    # eta scales ONLY the eq. 5 prompt and the eq. 9 new-work term; the
    # switch price and the queue backlog stay fixed, so the score is
    # affine in eta: score(eta) == score(0) + eta * (score(1) - score(0))
    base = np.asarray(ref.route_score_xla(**args))
    fixed = np.asarray(ref.route_score_xla(
        **args, eta=jnp.zeros(b, jnp.float32)))
    e = np.asarray(eta)[:, None]
    np.testing.assert_allclose(expect, fixed + e * (base - fixed),
                               rtol=1e-5)
    for backend_fn in (ref.route_score_xla,
                       lambda **kw: route_score(**kw, interpret=True)):
        ones = np.asarray(backend_fn(**args, eta=jnp.ones(b, jnp.float32)))
        absent = np.asarray(backend_fn(**args))
        np.testing.assert_array_equal(ones, absent)


@pytest.mark.parametrize("b,n,k", [(7, 5, 4), (130, 33, 5)])
def test_kernel_beta_refusal_masks_misses(b, n, k):
    """beta = False prices every NON-resident pair +inf (the residency
    gate is a select, so hits keep their finite score untouched);
    all-True beta is BITWISE the knob-absent call."""
    rng = np.random.default_rng(3 * b + n)
    args = _random_case(rng, b, n, k, jnp.float32)
    beta = jnp.asarray(rng.random(b) < 0.5)
    expect = np.asarray(ref.route_score_xla(**args, beta=beta))
    got = np.asarray(route_score(**args, beta=beta, interpret=True))
    np.testing.assert_array_equal(np.isinf(got), np.isinf(expect))
    fin = np.isfinite(expect)
    np.testing.assert_allclose(got[fin], expect[fin], rtol=1e-6)
    res = np.asarray(args["resident"])[
        :, np.asarray(args["model"])].T            # (B, N) hit map
    refused = ~np.asarray(beta)[:, None] & ~res
    np.testing.assert_array_equal(np.isinf(expect), refused)
    base = np.asarray(ref.route_score_xla(**args))
    np.testing.assert_array_equal(expect[~refused], base[~refused])
    for backend_fn in (ref.route_score_xla,
                       lambda **kw: route_score(**kw, interpret=True)):
        always = np.asarray(backend_fn(**args, beta=jnp.ones(b, bool)))
        absent = np.asarray(backend_fn(**args))
        np.testing.assert_array_equal(always, absent)


def test_beta_without_size_bits_raises():
    """The switch-free base has no eq. 7 term to refuse."""
    rng = np.random.default_rng(5)
    args = _random_case(rng, 9, 4, 4, jnp.float32)
    args["size_bits"] = None
    with pytest.raises(ValueError, match="beta"):
        ref.route_score_xla(**args, beta=jnp.ones(9, bool))
    with pytest.raises(ValueError, match="beta"):
        route_score(**args, beta=jnp.ones(9, bool), interpret=True)


def test_eta_beta_ragged_shapes_combined():
    """Both knobs together on a ragged (B, N, K) grid, with cells."""
    rng = np.random.default_rng(29)
    args = _random_case(rng, 257, 17, 9, jnp.float32, cells=3)
    eta = jnp.asarray(
        rng.choice([0.25, 0.5, 1.0], size=257), jnp.float32)
    beta = jnp.asarray(rng.random(257) < 0.5)
    expect = np.asarray(ref.route_score_xla(**args, eta=eta, beta=beta))
    got = np.asarray(route_score(**args, eta=eta, beta=beta,
                                 interpret=True))
    assert got.shape == (257, 17)
    np.testing.assert_array_equal(np.isinf(got), np.isinf(expect))
    fin = np.isfinite(expect)
    assert fin.any() and not fin.all()
    np.testing.assert_allclose(got[fin], expect[fin], rtol=1e-6)


def test_custom_block_shapes():
    """Tile sizes are knobs; odd blocks still reproduce the reference."""
    rng = np.random.default_rng(17)
    args = _random_case(rng, 70, 40, 4, jnp.float32)
    expect = np.asarray(ref.route_score_xla(**args))
    got = np.asarray(
        route_score(**args, interpret=True, block_b=32, block_n=16)
    )
    np.testing.assert_allclose(got, expect, rtol=1e-6)


@pytest.mark.parametrize("backend", ["xla", "pallas-interpret"])
def test_score_matrix_backend_dispatch(backend):
    """``score_matrix`` exposes the same contraction per backend."""
    rng = np.random.default_rng(19)
    from repro.launch.serve import make_multicell_fleet

    fleet = make_multicell_fleet(3, 2, CATALOG)
    params, state = br.fleet_from_servers(fleet, CATALOG)
    b = 29
    reqs = br.RequestBatch(
        model=jnp.asarray(rng.integers(0, len(CATALOG), b), jnp.int32),
        prompt_bits=jnp.asarray(rng.uniform(1e5, 1e6, b), jnp.float32),
        gen_tokens=jnp.asarray(rng.integers(1, 64, b), jnp.float32),
        cell=jnp.asarray(rng.integers(0, 3, b), jnp.int32),
    )
    got = np.asarray(br.score_matrix(params, state, reqs, backend=backend))
    expect = np.asarray(br.score_matrix(params, state, reqs, backend="xla"))
    np.testing.assert_allclose(got, expect, rtol=1e-6)
    assert np.isinf(got).any()  # the cell mask reached the kernel


def test_ops_dispatch_rejects_unknown_backend():
    with pytest.raises(ValueError):
        br.resolve_backend("cuda")


def test_env_knob_resolves_backend(monkeypatch):
    monkeypatch.setenv(br.BACKEND_ENV, "pallas-interpret")
    assert br.resolve_backend(None) == "pallas-interpret"
    monkeypatch.delenv(br.BACKEND_ENV)
    assert br.resolve_backend(None) == "xla"
    assert br.resolve_backend("pallas") == "pallas"
