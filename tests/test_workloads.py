"""Scenario workload subsystem: determinism, stream invariants, drift
semantics, the windowed simulator's equivalence to the unwindowed
``route_batch`` oracle, and the per-window stats variant."""
import hashlib
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core import batch_router as br
from repro.core.catalog import build_catalog
from repro.launch.serve import make_multicell_fleet, serve
from repro.workloads import (ScenarioSpec, compile_scenario, generators,
                             get_scenario, list_scenarios, simulate)

EDGE_ARCHS = ["smollm_135m", "starcoder2_3b", "mamba2_2p7b",
              "musicgen_medium"]


def _stream_digest(name, seed, n, num_models, num_cells):
    spec = get_scenario(name, num_requests=n)
    reqs = compile_scenario(spec, seed=seed, num_models=num_models,
                            num_cells=num_cells)
    h = hashlib.sha256()
    for field in br.RequestBatch._fields:
        arr = getattr(reqs, field)
        if arr is not None:
            h.update(np.ascontiguousarray(np.asarray(arr)).tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# registry + stream invariants
# ---------------------------------------------------------------------------
def test_registry_has_the_named_scenarios():
    names = set(list_scenarios())
    assert {"steady", "bursty", "diurnal", "flash-crowd",
            "popularity-drift", "hotspot-cell"} <= names


@pytest.mark.parametrize("name", list_scenarios())
def test_scenarios_compile_with_sound_streams(name):
    """Every registered scenario lowers to a well-formed RequestBatch:
    right dtypes/shapes, in-range columns, and NON-DECREASING arrival
    stamps (the sequential-commit router assumes stream order)."""
    n, k, cells = 257, 4, 2
    reqs = compile_scenario(get_scenario(name, num_requests=n), seed=11,
                            num_models=k, num_cells=cells)
    assert reqs.model.shape == (n,) and reqs.model.dtype == np.int32
    model = np.asarray(reqs.model)
    assert ((model >= 0) & (model < k)).all()
    prompt = np.asarray(reqs.prompt_bits)
    assert ((prompt >= 1e5) & (prompt < 1e6)).all()
    gen = np.asarray(reqs.gen_tokens)
    assert ((gen >= 8) & (gen < 128)).all()
    cell = np.asarray(reqs.cell)
    assert ((cell >= 0) & (cell < cells)).all()
    arr = np.asarray(reqs.arrival_s)
    assert (np.diff(arr) >= 0).all(), f"{name} arrivals not sorted"
    assert arr[0] >= 0.0
    # single-cell topologies compile the cell column away
    single = compile_scenario(get_scenario(name, num_requests=16), seed=0,
                              num_models=k, num_cells=1)
    assert single.cell is None


def test_same_spec_seed_is_bit_identical_in_process():
    a = _stream_digest("bursty", 5, 300, 4, 2)
    b = _stream_digest("bursty", 5, 300, 4, 2)
    assert a == b
    assert a != _stream_digest("bursty", 6, 300, 4, 2)  # seed matters


def test_same_spec_seed_is_bit_identical_across_processes():
    """The determinism contract: (spec, seed) regenerates the stream
    bit-identically in a FRESH interpreter."""
    digest = _stream_digest("popularity-drift", 3, 200, 4, 2)
    repo = Path(__file__).resolve().parents[1]
    code = (
        "import sys; sys.path.insert(0, 'tests'); "
        "from test_workloads import _stream_digest; "
        "print(_stream_digest('popularity-drift', 3, 200, 4, 2))"
    )
    out = subprocess.run(
        [sys.executable, "-c", code], cwd=repo, capture_output=True,
        text=True, check=True,
        env=dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu"),
    )
    assert out.stdout.strip().splitlines()[-1] == digest


def test_component_independence():
    """Changing the arrival process must not reshuffle the other
    columns (each component draws from its own SeedSequence child)."""
    a = compile_scenario(ScenarioSpec(arrival="poisson", num_requests=100),
                         seed=9, num_models=4, num_cells=2)
    b = compile_scenario(
        ScenarioSpec(arrival="flash", spike_start_s=0.05, num_requests=100),
        seed=9, num_models=4, num_cells=2,
    )
    assert np.array_equal(np.asarray(a.model), np.asarray(b.model))
    assert np.array_equal(np.asarray(a.cell), np.asarray(b.cell))
    assert not np.array_equal(np.asarray(a.arrival_s),
                              np.asarray(b.arrival_s))


# ---------------------------------------------------------------------------
# generator semantics
# ---------------------------------------------------------------------------
def test_zipf_popularity_sums_to_one_and_ranks_decrease():
    p = generators.zipf_popularity(6, 1.5)
    assert np.isclose(p.sum(), 1.0)
    assert (np.diff(p) < 0).all()
    assert np.allclose(generators.zipf_popularity(5, 0.0), 0.2)  # uniform


def test_drifting_popularity_reorders_ranks():
    rng = np.random.default_rng(0)
    probs, perms = generators.drifting_popularity(rng, 8, 6, 1.5)
    assert np.allclose(probs.sum(axis=1), 1.0)
    base = generators.zipf_popularity(6, 1.5)
    # every row holds the same Zipf masses, re-assigned to models
    assert np.allclose(np.sort(probs, axis=1), np.sort(base))
    for w in range(8):  # perms[w, r] holds rank r's mass in window w
        assert np.allclose(probs[w, perms[w]], base)
    # the rank order actually changes across windows
    assert any(not np.array_equal(perms[0], perms[w]) for w in range(1, 8))


def test_flash_crowd_spikes_and_mmpp_bursts():
    rng = np.random.default_rng(2)
    arr = generators.flash_crowd_arrivals(rng, 2000, rate=100.0,
                                          spike_start_s=3.0, spike_dur_s=1.0,
                                          spike_mult=20.0)
    in_spike = ((arr >= 3.0) & (arr < 4.0)).sum()          # ~2000/s * 1s
    before = (arr < 3.0).sum() / 3.0                       # ~100/s
    assert in_spike / 1.0 > 5 * before
    arr = generators.mmpp_arrivals(np.random.default_rng(3), 2000, 50.0,
                                   2000.0, 2.0, 0.25)
    gaps = np.diff(arr)
    assert (gaps >= 0).all()
    # burst sojourns produce much denser gaps than quiet ones
    assert np.percentile(gaps, 10) < np.percentile(gaps, 90) / 5


def test_hotspot_cell_skew():
    reqs = compile_scenario(get_scenario("hotspot-cell", num_requests=2000),
                            seed=0, num_models=4, num_cells=4)
    share = (np.asarray(reqs.cell) == 0).mean()
    assert 0.6 < share < 0.8  # spec: 70% of traffic on cell 0


def test_burst_train_matches_legacy_fixture_construction():
    """The policy_serving port: generators consumed in the canonical
    order reproduce the legacy hand-rolled numpy stream bit for bit."""
    n, burst, gap = 512, 64, 0.5
    rng = np.random.default_rng(7)
    arrivals = generators.burst_train_arrivals(rng, n, burst, gap)
    fields = generators.stream_fields(rng, n, 4, num_cells=2)
    rng = np.random.default_rng(7)
    legacy_arr = np.sort((np.arange(n) // burst) * gap
                         + rng.uniform(0.0, 1e-3, n))
    assert np.array_equal(arrivals, legacy_arr)
    assert np.array_equal(fields["model"], rng.integers(0, 4, n))
    assert np.array_equal(fields["prompt_bits"], rng.uniform(1e5, 1e6, n))
    assert np.array_equal(fields["gen_tokens"], rng.integers(8, 128, n))
    assert np.array_equal(fields["cell"], rng.integers(0, 2, n))


# ---------------------------------------------------------------------------
# simulator: windowed episode == unwindowed oracle (drain-free)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["greedy", "load"])
def test_windowed_simulation_bit_matches_single_call(policy):
    catalog = build_catalog(EDGE_ARCHS)
    fleet = make_multicell_fleet(2, 3, catalog, drain_rate=0.0)
    params, state0 = br.fleet_from_servers(fleet, catalog)
    reqs = compile_scenario(get_scenario("bursty", num_requests=300), seed=1,
                            num_models=len(catalog), num_cells=2)
    state_w, out_w, series = simulate(params, state0, reqs, policy=policy,
                                      window_requests=64,
                                      cloud_index=len(fleet) - 1)
    state_1, out_1 = br.route_batch(params, state0, reqs, policy=policy)
    assert np.array_equal(np.asarray(out_w.choice), np.asarray(out_1.choice))
    assert np.array_equal(np.asarray(out_w.latency),
                          np.asarray(out_1.latency))
    assert np.array_equal(np.asarray(out_w.hit), np.asarray(out_1.hit))
    for a, b in zip(jax.tree.leaves(state_w), jax.tree.leaves(state_1)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # series shape checks: 300 requests in 64-windows -> 5 windows
    assert series.requests.tolist() == [64, 64, 64, 64, 44]
    assert (series.window_start_s[1:] >= series.window_end_s[:-1]).all()


def test_window_stats_matches_stats():
    catalog = build_catalog(EDGE_ARCHS)
    fleet = make_multicell_fleet(2, 2, catalog, drain_rate=0.0)
    params, state0 = br.fleet_from_servers(fleet, catalog)
    reqs = compile_scenario(get_scenario("steady", num_requests=128), seed=2,
                            num_models=len(catalog), num_cells=2)
    _, out = br.route_batch(params, state0, reqs)
    cloud = len(fleet) - 1
    whole = br.stats(out, cloud_index=cloud)
    one = br.window_stats(out, np.zeros(128, np.int64), 1,
                          cloud_index=cloud)
    assert one["requests"].tolist() == [128]
    for key in ("mean_latency", "completion_rate", "residency_hit_rate",
                "cloud_fallback_rate"):
        assert np.isclose(one[key][0], whole[key]), key
    # two windows partition the stream: counts add up, rates average back
    two = br.window_stats(out, (np.arange(128) >= 64).astype(np.int64), 2,
                          cloud_index=cloud)
    assert two["requests"].sum() == 128
    assert np.isclose(two["residency_hit_rate"].mean(),
                      whole["residency_hit_rate"])
    # completed_means: a constant column averages back to the constant
    extra = br.window_stats(out, np.zeros(128, np.int64), 1,
                            completed_means={"x": np.full(128, 2.5)})
    assert np.isclose(extra["x"][0], 2.5)


def test_empty_and_rejected_windows_are_masked():
    out = br.RouteOutcome(
        choice=np.array([0, -1, 1, -1], np.int32),
        latency=np.array([1.0, np.inf, 3.0, np.inf]),
        hit=np.array([True, False, True, False]),
    )
    ws = br.window_stats(out, np.array([0, 0, 1, 1]), 3)
    assert np.allclose(ws["mean_latency"][:2], [1.0, 3.0])  # inf masked out
    assert ws["mean_latency"][2] == np.inf                  # empty window
    assert np.allclose(ws["completion_rate"], [0.5, 0.5, 0.0])
    # hit rate over COMPLETED requests: each window's one completed
    # request hit, so the rejections must not drag the rate to 0.5
    assert np.allclose(ws["residency_hit_rate"][:2], [1.0, 1.0])
    assert np.isnan(ws["residency_hit_rate"][2])            # empty window


def test_fully_rejected_window_reports_nan_not_zero():
    """A fully-rejected flash-crowd window has no completed requests: a
    completed-mean of 0.0 would read as impossibly perfect (zero energy
    per request) — it must be nan, consistent with inf mean_latency."""
    out = br.RouteOutcome(
        choice=np.array([0, 1, -1, -1], np.int32),
        latency=np.array([1.0, 3.0, np.inf, np.inf]),
        hit=np.array([True, False, False, False]),
    )
    ws = br.window_stats(out, np.array([0, 0, 1, 1]), 2,
                         completed_means={"energy_j": np.array(
                             [2.0, 4.0, 0.0, 0.0])})
    assert np.isclose(ws["energy_j"][0], 3.0)
    assert np.isnan(ws["energy_j"][1])       # zero completed -> nan
    assert ws["mean_latency"][1] == np.inf
    assert np.isnan(ws["residency_hit_rate"][1])
    assert ws["completion_rate"][1] == 0.0   # the rate itself is real


# ---------------------------------------------------------------------------
# the paper's switching dynamic + serve wiring
# ---------------------------------------------------------------------------
def test_popularity_drift_lowers_hit_rate():
    """The acceptance dynamic: under the same greedy policy and a fleet
    whose per-cell cache cannot hold the whole catalogue, drifting
    popularity forces eq. 7 switches that steady traffic avoids."""
    from benchmarks.scenario_suite import (ARCHS, CACHE_SLOTS, CELLS,
                                           DRAIN_RATE, SERVERS_PER_CELL)

    catalog = build_catalog(ARCHS)
    fleet = make_multicell_fleet(CELLS, SERVERS_PER_CELL, catalog,
                                 slots=CACHE_SLOTS, drain_rate=DRAIN_RATE,
                                 cloud=False)
    params, state0 = br.fleet_from_servers(fleet, catalog)
    hit = {}
    for name in ("steady", "popularity-drift"):
        reqs = compile_scenario(get_scenario(name), seed=0,
                                num_models=len(catalog), num_cells=CELLS)
        _, out, _ = simulate(params, state0, reqs, policy="greedy")
        hit[name] = br.stats(out)["residency_hit_rate"]
    assert hit["popularity-drift"] < hit["steady"] - 0.02, hit


def test_serve_scenario_roundtrip():
    """serve(--scenario, --seed) wires the compiled stream end to end
    and is reproducible: same seed, same stats; different seed, a
    different stream."""
    kw = dict(num_requests=48, n_servers=2, execute=False, n_cells=2,
              drain_rate=2e4, scenario="hotspot-cell")
    a = serve(seed=5, **kw)
    b = serve(seed=5, **kw)
    c = serve(seed=6, **kw)
    assert a["scenario"] == "hotspot-cell" and a["seed"] == 5
    for key in ("mean_latency", "residency_hit_rate", "completion_rate",
                "cloud_fallback_rate"):
        assert a[key] == b[key], key
    assert any(a[k] != c[k] for k in ("mean_latency", "cloud_fallback_rate"))


def test_scenario_suite_registered_in_run():
    from benchmarks import run as bench_run

    assert "scenarios" in dict(bench_run.SECTIONS)
