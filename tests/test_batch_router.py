"""Batched router vs the scalar ModelAwareRouter oracle — exact equivalence.

The batched ``lax.scan`` path must reproduce the scalar reference request
for request: same choices, same predicted latencies, same residency sets,
same LRU evictions, same queues — over randomised request streams, fleet
shapes and cache sizes. Integer decisions are compared exactly; latencies
under x64 to within a couple of ulps (XLA emits FMAs the Python oracle
cannot). The float32 fast path must still agree on every integer decision.
"""
import copy

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import batch_router as br
from repro.core.catalog import build_catalog
from repro.core.router import EdgeServer, ModelAwareRouter, Request

CATALOG = build_catalog(
    ["smollm_135m", "starcoder2_3b", "mamba2_2p7b", "musicgen_medium"]
)


def _random_fleet(rng, n_servers, cache_slots):
    return [
        EdgeServer(
            name=f"es{i}",
            flops_per_s=float(rng.uniform(5e13, 2e14)),
            cache_slots=cache_slots,
            uplink_bps=float(rng.uniform(5e7, 2e8)),
            backhaul_bps=float(rng.uniform(5e8, 2e9)),
            resident=list(
                rng.choice(len(CATALOG), size=cache_slots, replace=False)
            ),
        )
        for i in range(n_servers)
    ]


def _random_stream(rng, n_requests):
    return (
        rng.integers(0, len(CATALOG), n_requests),
        rng.uniform(1e5, 1e6, n_requests),
        rng.integers(1, 64, n_requests),
    )


def _run_scalar(servers, models, bits, toks, drain, policy="greedy",
                actor=None):
    router = ModelAwareRouter(copy.deepcopy(servers), CATALOG,
                              policy=policy, actor=actor)
    choices, lats, hits = [], [], []
    for m, b, t in zip(models, bits, toks):
        srv_resident = [int(m) in s.resident for s in router.servers]
        c, l = router.route(Request(int(m), float(b), int(t)))
        choices.append(c)
        lats.append(l)
        hits.append(srv_resident[c])
        router.drain(drain)
    return router, np.array(choices), np.array(lats), np.array(hits)


def _run_batched(servers, models, bits, toks, drain, dtype, policy="greedy",
                 actor=None):
    params, state = br.fleet_from_servers(servers, CATALOG)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, dtype),
        gen_tokens=jnp.asarray(toks, dtype),
    )
    return br.route_batch(params, state, reqs, drain, policy=policy,
                          actor=actor)


def _assert_fleet_state_matches(router, state):
    resident = np.asarray(state.resident)
    last_use = np.asarray(state.last_use)
    for i, srv in enumerate(router.servers):
        assert set(np.nonzero(resident[i])[0]) == set(srv.resident), i
        for m in srv.resident:
            if m in srv.last_use:  # touched models carry the exact clock
                assert last_use[i, m] == srv.last_use[m], (i, m)
    np.testing.assert_allclose(
        np.asarray(state.queue_tokens),
        np.array([s.queue_tokens for s in router.servers]),
        rtol=1e-6,
    )


@pytest.mark.parametrize("seed,n_servers,cache_slots", [
    (0, 2, 1), (1, 3, 2), (2, 5, 2), (3, 8, 3), (4, 4, 1), (5, 6, 4),
])
def test_batched_matches_scalar_oracle_exactly(seed, n_servers, cache_slots):
    """x64: choices, latencies, residency, LRU clocks and queues all equal."""
    with enable_x64():
        rng = np.random.default_rng(seed)
        servers = _random_fleet(rng, n_servers, cache_slots)
        models, bits, toks = _random_stream(rng, 300)
        drain = float(rng.uniform(0.0, 50.0))

        router, sc_choice, sc_lat, sc_hit = _run_scalar(
            servers, models, bits, toks, drain
        )
        state, out = _run_batched(
            servers, models, bits, toks, drain, jnp.float64
        )

        np.testing.assert_array_equal(np.asarray(out.choice), sc_choice)
        # XLA fuses mul+add into an FMA the Python oracle can't express;
        # latencies agree to the last couple of ulps, decisions exactly.
        np.testing.assert_allclose(np.asarray(out.latency), sc_lat,
                                   rtol=1e-12, atol=0.0)
        np.testing.assert_array_equal(np.asarray(out.hit), sc_hit)
        _assert_fleet_state_matches(router, state)


@pytest.mark.parametrize("seed", [10, 11, 12])
def test_float32_fast_path_same_decisions(seed):
    """The f32 serving path must agree on every choice/eviction (decisions
    are integer-valued; f32 rounding never flips a non-degenerate argmin)."""
    rng = np.random.default_rng(seed)
    servers = _random_fleet(rng, 4, 2)
    models, bits, toks = _random_stream(rng, 400)

    router, sc_choice, _, sc_hit = _run_scalar(servers, models, bits, toks, 5.0)
    state, out = _run_batched(servers, models, bits, toks, 5.0, jnp.float32)

    np.testing.assert_array_equal(np.asarray(out.choice), sc_choice)
    np.testing.assert_array_equal(np.asarray(out.hit), sc_hit)
    resident = np.asarray(state.resident)
    for i, srv in enumerate(router.servers):
        assert set(np.nonzero(resident[i])[0]) == set(srv.resident), i


def test_actor_policy_matches_scalar_actor():
    """A (traceable) actor drives both routers to identical streams."""

    def actor(obs, lats):
        # busiest-server actor: pathological but deterministic in both paths
        queue = jnp.reshape(jnp.asarray(obs), (-1, 3))[:, 1]
        return jnp.argmax(queue)

    rng = np.random.default_rng(7)
    servers = _random_fleet(rng, 5, 2)
    models, bits, toks = _random_stream(rng, 120)

    router, sc_choice, _, _ = _run_scalar(
        servers, models, bits, toks, 0.0, policy="actor", actor=actor
    )
    state, out = _run_batched(
        servers, models, bits, toks, 0.0, jnp.float32, policy="actor",
        actor=actor,
    )
    np.testing.assert_array_equal(np.asarray(out.choice), sc_choice)
    _assert_fleet_state_matches(router, state)


def test_load_policy_balances_queues():
    rng = np.random.default_rng(8)
    servers = _random_fleet(rng, 4, 2)
    models, bits, toks = _random_stream(rng, 200)
    state, out = _run_batched(
        servers, models, bits, toks, 0.0, jnp.float32, policy="load"
    )
    counts = np.bincount(np.asarray(out.choice), minlength=4)
    # least-loaded dispatch spreads work across every server
    assert counts.min() > 0
    queues = np.asarray(state.queue_tokens)
    assert queues.max() < 2.0 * queues.min() + float(np.max(toks))


def test_score_matrix_matches_candidate_latency():
    """One-shot (B, N) scoring == the oracle's per-candidate pricing."""
    with enable_x64():
        rng = np.random.default_rng(9)
        servers = _random_fleet(rng, 6, 2)
        models, bits, toks = _random_stream(rng, 50)
        router = ModelAwareRouter(copy.deepcopy(servers), CATALOG)
        expected = np.array([
            [router._candidate_latency(s, Request(int(m), float(b), int(t)))
             for s in router.servers]
            for m, b, t in zip(models, bits, toks)
        ])
        params, state = br.fleet_from_servers(servers, CATALOG)
        reqs = br.RequestBatch(
            model=jnp.asarray(models, jnp.int32),
            prompt_bits=jnp.asarray(bits, jnp.float64),
            gen_tokens=jnp.asarray(toks, jnp.float64),
        )
        got = np.asarray(br.score_matrix(params, state, reqs))
        np.testing.assert_allclose(got, expected, rtol=1e-12, atol=0.0)


def test_per_request_drain_vector():
    """A (B,) drain schedule matches per-request scalar drains."""
    with enable_x64():
        rng = np.random.default_rng(13)
        servers = _random_fleet(rng, 3, 2)
        models, bits, toks = _random_stream(rng, 80)
        drains = rng.uniform(0.0, 30.0, 80)

        router = ModelAwareRouter(copy.deepcopy(servers), CATALOG)
        sc_choice = []
        for m, b, t, d in zip(models, bits, toks, drains):
            c, _ = router.route(Request(int(m), float(b), int(t)))
            sc_choice.append(c)
            router.drain(float(d))

        params, state = br.fleet_from_servers(servers, CATALOG)
        reqs = br.RequestBatch(
            model=jnp.asarray(models, jnp.int32),
            prompt_bits=jnp.asarray(bits, jnp.float64),
            gen_tokens=jnp.asarray(toks, jnp.float64),
        )
        state, out = br.route_batch(params, state, reqs, jnp.asarray(drains))
        np.testing.assert_array_equal(np.asarray(out.choice),
                                      np.array(sc_choice))
        _assert_fleet_state_matches(router, state)


def test_midstream_snapshot_continues_oracle():
    """Snapshotting a scalar router mid-stream (warm last_use clocks) and
    continuing batched must keep matching — requires threading the oracle's
    clock, or the new batch's clocks would sort BELOW existing residents'."""
    with enable_x64():
        rng = np.random.default_rng(21)
        servers = _random_fleet(rng, 4, 2)
        models, bits, toks = _random_stream(rng, 240)

        router = ModelAwareRouter(copy.deepcopy(servers), CATALOG)
        sc_choice = []
        for m, b, t in zip(models, bits, toks):
            c, _ = router.route(Request(int(m), float(b), int(t)))
            sc_choice.append(c)

        half = 120
        warm = ModelAwareRouter(copy.deepcopy(servers), CATALOG)
        for m, b, t in zip(models[:half], bits[:half], toks[:half]):
            warm.route(Request(int(m), float(b), int(t)))
        params, state = br.fleet_from_servers(warm.servers, CATALOG,
                                              clock=warm.clock)
        reqs = br.RequestBatch(
            model=jnp.asarray(models[half:], jnp.int32),
            prompt_bits=jnp.asarray(bits[half:], jnp.float64),
            gen_tokens=jnp.asarray(toks[half:], jnp.float64),
        )
        state, out = br.route_batch(params, state, reqs)
        np.testing.assert_array_equal(np.asarray(out.choice),
                                      np.array(sc_choice[half:]))
        _assert_fleet_state_matches(router, state)


@pytest.mark.parametrize("backend", ["xla", "pallas-interpret"])
@pytest.mark.parametrize("chunk", [64, 100])
def test_chunked_matches_scalar_oracle(chunk, backend):
    """The two-phase chunked commit (incl. a chunk that does NOT divide
    B, exercising the inert padding tail) reproduces the oracle's
    choices, hits, residency, LRU clocks and queues under BOTH scoring
    backends; latencies agree to a few ulps (the chunked path
    re-associates eq. 9, see batch_router docstring)."""
    with enable_x64():
        rng = np.random.default_rng(31)
        servers = _random_fleet(rng, 5, 2)
        models, bits, toks = _random_stream(rng, 300)
        drain = float(rng.uniform(0.0, 50.0))

        router, sc_choice, sc_lat, sc_hit = _run_scalar(
            servers, models, bits, toks, drain
        )
        params, state = br.fleet_from_servers(servers, CATALOG)
        reqs = br.RequestBatch(
            model=jnp.asarray(models, jnp.int32),
            prompt_bits=jnp.asarray(bits, jnp.float64),
            gen_tokens=jnp.asarray(toks, jnp.float64),
        )
        state, out = br.route_batch(params, state, reqs, drain, chunk=chunk,
                                    backend=backend)
        np.testing.assert_array_equal(np.asarray(out.choice), sc_choice)
        np.testing.assert_array_equal(np.asarray(out.hit), sc_hit)
        np.testing.assert_allclose(np.asarray(out.latency), sc_lat,
                                   rtol=1e-12, atol=0.0)
        _assert_fleet_state_matches(router, state)


def test_chunked_matches_legacy_scan_all_policies():
    """chunk=c and chunk=None agree decision-for-decision per policy."""

    def busiest_actor(obs, lats):
        queue = jnp.reshape(jnp.asarray(obs), (-1, 3))[:, 1]
        return jnp.argmax(queue)

    rng = np.random.default_rng(33)
    servers = _random_fleet(rng, 6, 2)
    models, bits, toks = _random_stream(rng, 250)
    params, state = br.fleet_from_servers(servers, CATALOG)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
    )
    for policy, actor in [("greedy", None), ("load", None),
                          ("actor", busiest_actor)]:
        s0, o0 = br.route_batch(params, state, reqs, 3.0, policy=policy,
                                actor=actor)
        s1, o1 = br.route_batch(params, state, reqs, 3.0, policy=policy,
                                actor=actor, chunk=64)
        np.testing.assert_array_equal(np.asarray(o0.choice),
                                      np.asarray(o1.choice), err_msg=policy)
        np.testing.assert_array_equal(np.asarray(s0.resident),
                                      np.asarray(s1.resident), err_msg=policy)
        np.testing.assert_allclose(np.asarray(s0.queue_tokens),
                                   np.asarray(s1.queue_tokens), rtol=1e-6)


def test_stats_masks_rejected_requests():
    """Rejected requests must not poison mean_latency OR deflate the
    hit rate; completion_rate reports them (the paper's third headline
    metric). Rejected requests are forced hit=False by the router, so
    residency_hit_rate averages over COMPLETED requests only."""
    out = br.RouteOutcome(
        choice=jnp.asarray([0, -1, 2, -1], jnp.int32),
        latency=jnp.asarray([1.0, jnp.inf, 3.0, jnp.inf], jnp.float32),
        hit=jnp.asarray([True, False, False, False]),
    )
    got = br.stats(out)
    assert got["mean_latency"] == pytest.approx(2.0)
    assert got["completion_rate"] == pytest.approx(0.5)
    assert got["residency_hit_rate"] == pytest.approx(0.5)  # 1 of 2 done

    none = br.stats(out._replace(
        choice=jnp.full((4,), -1, jnp.int32),
        latency=jnp.full((4,), jnp.inf, jnp.float32),
    ))
    assert none["completion_rate"] == 0.0
    assert np.isinf(none["mean_latency"])  # no finite sample to average
    assert np.isnan(none["residency_hit_rate"])  # nothing completed


def test_route_batch_unroll_is_a_knob():
    """unroll only changes the compiled schedule, never a decision."""
    rng = np.random.default_rng(35)
    servers = _random_fleet(rng, 4, 2)
    models, bits, toks = _random_stream(rng, 120)
    params, state = br.fleet_from_servers(servers, CATALOG)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
    )
    ref_state, ref_out = br.route_batch(params, state, reqs)
    for unroll in (1, 4, 32):
        s, o = br.route_batch(params, state, reqs, unroll=unroll)
        np.testing.assert_array_equal(np.asarray(o.choice),
                                      np.asarray(ref_out.choice))
        np.testing.assert_array_equal(np.asarray(s.last_use),
                                      np.asarray(ref_state.last_use))


@pytest.mark.slow
def test_fleet_scale_single_call():
    """Acceptance shape: B=4096 requests over N=64 servers, one jitted call,
    still bit-identical to the scalar oracle on choices and residency —
    on both the single-scan path and the chunked two-phase commit."""
    rng = np.random.default_rng(42)
    servers = _random_fleet(rng, 64, 2)
    models, bits, toks = _random_stream(rng, 4096)

    router, sc_choice, _, _ = _run_scalar(servers, models, bits, toks, 0.0)
    state, out = _run_batched(servers, models, bits, toks, 0.0, jnp.float32)

    np.testing.assert_array_equal(np.asarray(out.choice), sc_choice)
    resident = np.asarray(state.resident)
    for i, srv in enumerate(router.servers):
        assert set(np.nonzero(resident[i])[0]) == set(srv.resident), i

    params, st0 = br.fleet_from_servers(servers, CATALOG)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
    )
    st_c, out_c = br.route_batch(params, st0, reqs, 0.0, chunk=256)
    np.testing.assert_array_equal(np.asarray(out_c.choice), sc_choice)
    np.testing.assert_array_equal(np.asarray(st_c.resident), resident)


@pytest.mark.parametrize("chunk", [None, 64])
def test_out_of_range_policy_falls_back_to_argmin(chunk):
    """Untopologied (has_cells=False) fleets: a policy emitting an index
    >= N (or negative) used to be silently clamped to server N-1 by XLA
    gather semantics and committed with no signal. It now falls back to
    the masked greedy argmin — the same fallback the out-of-cell clamp
    applies — on both the single-scan and chunked paths."""

    def rogue(lats, obs, queue):
        return jnp.int32(99)  # far out of range, every request

    rng = np.random.default_rng(57)
    servers = _random_fleet(rng, 4, 2)
    models, bits, toks = _random_stream(rng, 150)
    params, state = br.fleet_from_servers(servers, CATALOG)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
    )
    s_rogue, o_rogue = br.route_batch(params, state, reqs, policy=rogue,
                                      chunk=chunk)
    s_greedy, o_greedy = br.route_batch(params, state, reqs,
                                        policy="greedy", chunk=chunk)
    # the fallback IS the greedy argmin: identical stream and state
    np.testing.assert_array_equal(np.asarray(o_rogue.choice),
                                  np.asarray(o_greedy.choice))
    np.testing.assert_array_equal(np.asarray(o_rogue.hit),
                                  np.asarray(o_greedy.hit))
    np.testing.assert_array_equal(np.asarray(s_rogue.resident),
                                  np.asarray(s_greedy.resident))
    np.testing.assert_array_equal(np.asarray(s_rogue.queue_tokens),
                                  np.asarray(s_greedy.queue_tokens))
    assert (np.asarray(o_rogue.choice) < 4).all()
    assert (np.asarray(o_rogue.choice) >= 0).all()

    def negative(lats, obs, queue):
        return jnp.int32(-3)

    _, o_neg = br.route_batch(params, state, reqs, policy=negative,
                              chunk=chunk)
    np.testing.assert_array_equal(np.asarray(o_neg.choice),
                                  np.asarray(o_greedy.choice))
