"""Docs can't silently rot: import-check every example and assert the
commands/paths quoted in README.md and docs/serving.md (and their table
links) exist.

Import is cheap because every example keeps work behind a ``main()``
guard; actually executing them is the examples' own job (CI tier-2).
"""
import importlib.util
import re
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
README = (REPO / "README.md").read_text()
SERVING = (REPO / "docs" / "serving.md").read_text()
SCENARIOS = (REPO / "docs" / "scenarios.md").read_text()
SHARDING = (REPO / "docs" / "sharding.md").read_text()
ROBUSTNESS = (REPO / "docs" / "robustness.md").read_text()
EXAMPLES = sorted((REPO / "examples").glob("*.py"))


@pytest.mark.parametrize("path", EXAMPLES, ids=lambda p: p.name)
def test_example_imports_cleanly(path):
    """Every example module imports (no work outside the main() guard)."""
    name = f"_docs_example_{path.stem}"
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[name] = mod
    try:
        spec.loader.exec_module(mod)
        assert hasattr(mod, "main"), f"{path.name} has no main()"
    finally:
        sys.modules.pop(name, None)


def _quoted_commands(text):
    """All `python ...` invocations in fenced or inline code blocks."""
    fenced = re.findall(r"```(?:\w*\n)?(.*?)```", text, re.S)
    lines = [ln for block in fenced for ln in block.splitlines()]
    lines += re.findall(r"`([^`]+)`", text)
    cmds = []
    for ln in lines:
        ln = ln.strip().lstrip("$ ").replace("\\", " ")
        if "python " in ln:
            cmds.append(ln)
    return cmds


def _assert_commands_resolve(text, doc_name, needles):
    cmds = _quoted_commands(text)
    assert cmds, f"{doc_name} quotes no runnable commands"
    joined = "\n".join(cmds)
    # the core entry points the doc promises must be quoted
    for needle in needles:
        assert needle in joined, f"{doc_name} no longer quotes {needle}"
    for cmd in cmds:
        for tok in cmd.split():
            if tok.endswith(".py"):  # quoted script paths must exist
                assert (REPO / tok).is_file(), \
                    f"{doc_name} quotes missing {tok}"
    # quoted `python -m pkg.mod` modules must resolve to real files
    for cmd in cmds:
        if "pytest" in cmd:
            continue  # pytest's own -m takes a marker expression
        for mod in re.findall(r"-m\s+([\w.]+)", cmd):
            rel = Path(mod.replace(".", "/"))
            hit = any(
                (root / rel).with_suffix(".py").is_file()
                or (root / rel / "__main__.py").is_file()
                for root in (REPO, REPO / "src")
            )
            assert hit, f"{doc_name} quotes unresolvable module {mod}"


def test_readme_quotes_real_commands():
    _assert_commands_resolve(
        README, "README",
        ("examples/quickstart.py", "examples/serve_edge.py",
         "benchmarks.run", "benchmarks.policy_serving",
         "benchmarks.scenario_suite", "-m pytest",
         "--policy", "--scenario"),
    )


def test_scenarios_md_quotes_real_commands():
    """The scenario guide is pinned like the serving guide: quoted
    scripts/modules must exist and it must keep covering the serve
    scenario flag and the matrix benchmark."""
    _assert_commands_resolve(
        SCENARIOS, "docs/scenarios.md",
        ("repro.launch.serve", "benchmarks.scenario_suite",
         "--scenario", "--seed"),
    )


def test_scenarios_md_python_snippets_compile():
    blocks = re.findall(r"```python\n(.*?)```", SCENARIOS, re.S)
    assert blocks, "scenarios.md lost its python walkthrough"
    for block in blocks:
        compile(block, "scenarios.md", "exec")
        for mod in re.findall(r"^\s*(?:from|import)\s+(repro[\w.]*)",
                              block, re.M):
            assert importlib.util.find_spec(mod) is not None, \
                f"scenarios.md snippet imports unresolvable {mod}"


def test_readme_links_scenarios_guide():
    assert "docs/scenarios.md" in re.findall(r"\]\(([^)#`\s]+)\)", README), \
        "README no longer links the scenario guide"


def test_serving_md_quotes_real_commands():
    """The serving guide's commands are pinned like the README's: every
    quoted script/module must exist, and the guide must keep covering
    the policy flag, the actor-checkpoint form and the policy
    benchmark."""
    _assert_commands_resolve(
        SERVING, "docs/serving.md",
        ("repro.launch.serve", "benchmarks.policy_serving",
         "--policy actor:", "--drain-rate", "--chunk"),
    )


def test_serving_md_python_snippets_compile():
    """Fenced python blocks in the serving guide must at least parse,
    and every `from repro...` / `import repro...` they quote must
    resolve to a real module (the train->checkpoint->serve walkthrough
    can't silently rot)."""
    blocks = re.findall(r"```python\n(.*?)```", SERVING, re.S)
    assert blocks, "serving.md lost its python walkthrough"
    for block in blocks:
        compile(block, "serving.md", "exec")  # SyntaxError -> test fails
        for mod in re.findall(r"^\s*(?:from|import)\s+(repro[\w.]*)",
                              block, re.M):
            assert importlib.util.find_spec(mod) is not None, \
                f"serving.md snippet imports unresolvable {mod}"


def test_sharding_md_quotes_real_commands():
    """The sharding guide stays pinned like the others: quoted
    scripts/modules must exist and it must keep covering the serve
    mesh flag, the multidevice marker run and the fleet benchmark."""
    _assert_commands_resolve(
        SHARDING, "docs/sharding.md",
        ("repro.launch.serve", "benchmarks.fleet_scale",
         "--mesh", "-m multidevice",
         "--only fleet_scale --smoke"),
    )


def test_sharding_md_python_snippets_compile():
    blocks = re.findall(r"```python\n(.*?)```", SHARDING, re.S)
    assert blocks, "sharding.md lost its python walkthrough"
    for block in blocks:
        compile(block, "sharding.md", "exec")
        for mod in re.findall(r"^\s*(?:from|import)\s+(repro[\w.]*)",
                              block, re.M):
            assert importlib.util.find_spec(mod) is not None, \
                f"sharding.md snippet imports unresolvable {mod}"


def test_readme_links_sharding_guide():
    assert "docs/sharding.md" in re.findall(r"\]\(([^)#`\s]+)\)", README), \
        "README no longer links the sharding guide"


def test_robustness_md_quotes_real_commands():
    """The robustness guide stays pinned like the others: quoted
    scripts/modules must exist and it must keep covering the degraded
    suite, its CI smoke form and the SLO scenario serve."""
    _assert_commands_resolve(
        ROBUSTNESS, "docs/robustness.md",
        ("benchmarks.degraded_suite", "repro.launch.serve",
         "--only degraded_suite --smoke", "--scenario slo-mix"),
    )


def test_robustness_md_python_snippets_compile():
    blocks = re.findall(r"```python\n(.*?)```", ROBUSTNESS, re.S)
    assert blocks, "robustness.md lost its python walkthrough"
    for block in blocks:
        compile(block, "robustness.md", "exec")
        for mod in re.findall(r"^\s*(?:from|import)\s+(repro[\w.]*)",
                              block, re.M):
            assert importlib.util.find_spec(mod) is not None, \
                f"robustness.md snippet imports unresolvable {mod}"


def test_readme_links_robustness_guide():
    assert "docs/robustness.md" in re.findall(r"\]\(([^)#`\s]+)\)", README), \
        "README no longer links the robustness guide"


def test_serving_md_covers_eq16_action_contract():
    """The serving guide keeps the full eq. 16 action-contract table:
    one row per head, the column carriers, and the window-level
    evaluator for trained actors."""
    for needle in ("policies.actor_action_columns", "RequestBatch.eta",
                   "RequestBatch.beta", "local_flops_per_s",
                   "download_rate"):
        assert needle in SERVING, \
            f"docs/serving.md lost the eq. 16 contract piece {needle}"
    assert "| eq. 16 head |" in SERVING, \
        "docs/serving.md lost the eq. 16 policy-contract table"


def test_paper_map_covers_eq_rows():
    """paper_map.md keeps one row per printed equation the serving
    plane prices — including the eq. 1/2 task/model tuples and BOTH
    eq. 16 rows (observation AND the (target, eta, beta) action)."""
    paper_map = (REPO / "docs" / "paper_map.md").read_text()
    for needle in ("| eq. 1 |", "| eq. 2 |", "| eq. 3 |", "| eq. 4 |",
                   "action `(target, eta, beta)`",
                   "policies.actor_action_columns"):
        assert needle in paper_map, \
            f"docs/paper_map.md lost its {needle} row"


def test_ci_covers_policy_serving_smoke():
    """CI keeps the eq. 16 serving smoke: a toy actor asserting the
    eta/beta columns are honoured end to end."""
    ci = (REPO / ".github" / "workflows" / "ci.yml").read_text()
    assert "--only policy_serving --smoke" in ci


def test_ci_covers_degraded_smoke():
    """CI keeps the degraded-service smoke: one tiny fault-injected
    episode asserting admission AND outage rejections end to end."""
    ci = (REPO / ".github" / "workflows" / "ci.yml").read_text()
    assert "--only degraded_suite --smoke" in ci


def test_ci_covers_mesh_tier():
    """The CI workflow keeps the forced-8-device mesh job: the
    multidevice marker run and the fleet_scale smoke."""
    ci = (REPO / ".github" / "workflows" / "ci.yml").read_text()
    assert "--xla_force_host_platform_device_count=8" in ci
    assert "-m multidevice" in ci
    assert "--only fleet_scale --smoke" in ci


def test_readme_links_serving_guide():
    assert "docs/serving.md" in re.findall(r"\]\(([^)#`\s]+)\)", README), \
        "README no longer links the serving guide"


def test_readme_links_resolve():
    """Relative markdown links ([x](path)) in README + docs/ must exist."""
    for md in [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]:
        for target in re.findall(r"\]\(([^)#`\s]+)\)", md.read_text()):
            if "://" in target:
                continue
            resolved = (md.parent / target).resolve()
            assert resolved.exists(), f"{md.name} links missing {target}"


def test_readme_test_commands_match_roadmap():
    """README's tier-1 command stays in sync with ROADMAP's verify line."""
    roadmap = (REPO / "ROADMAP.md").read_text()
    assert "python -m pytest -x -q" in README
    assert "python -m pytest -x -q" in roadmap
    assert 'not slow' in README  # fast tier documented
