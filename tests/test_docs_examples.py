"""Docs can't silently rot: import-check every example and assert the
commands/paths quoted in README.md (and the README's table links) exist.

Import is cheap because every example keeps work behind a ``main()``
guard; actually executing them is the examples' own job (CI tier-2).
"""
import importlib.util
import re
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
README = (REPO / "README.md").read_text()
EXAMPLES = sorted((REPO / "examples").glob("*.py"))


@pytest.mark.parametrize("path", EXAMPLES, ids=lambda p: p.name)
def test_example_imports_cleanly(path):
    """Every example module imports (no work outside the main() guard)."""
    name = f"_docs_example_{path.stem}"
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[name] = mod
    try:
        spec.loader.exec_module(mod)
        assert hasattr(mod, "main"), f"{path.name} has no main()"
    finally:
        sys.modules.pop(name, None)


def _quoted_commands(text):
    """All `python ...` invocations in fenced or inline code blocks."""
    fenced = re.findall(r"```(?:\w*\n)?(.*?)```", text, re.S)
    lines = [ln for block in fenced for ln in block.splitlines()]
    lines += re.findall(r"`([^`]+)`", text)
    cmds = []
    for ln in lines:
        ln = ln.strip().lstrip("$ ").replace("\\", " ")
        if "python " in ln:
            cmds.append(ln)
    return cmds


def test_readme_quotes_real_commands():
    cmds = _quoted_commands(README)
    assert cmds, "README quotes no runnable commands"
    joined = "\n".join(cmds)
    # the core entry points the README promises must be quoted
    for needle in ("examples/quickstart.py", "examples/serve_edge.py",
                   "benchmarks.run", "-m pytest"):
        assert needle in joined, f"README no longer quotes {needle}"
    for cmd in cmds:
        for tok in cmd.split():
            if tok.endswith(".py"):  # quoted script paths must exist
                assert (REPO / tok).is_file(), f"README quotes missing {tok}"
    # quoted `python -m pkg.mod` modules must resolve to real files
    for mod in re.findall(r"-m\s+([\w.]+)", joined):
        if mod == "pytest":
            continue
        rel = Path(mod.replace(".", "/"))
        hit = any(
            (root / rel).with_suffix(".py").is_file()
            or (root / rel / "__main__.py").is_file()
            for root in (REPO, REPO / "src")
        )
        assert hit, f"README quotes unresolvable module {mod}"


def test_readme_links_resolve():
    """Relative markdown links ([x](path)) in README + docs/ must exist."""
    for md in [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]:
        for target in re.findall(r"\]\(([^)#`\s]+)\)", md.read_text()):
            if "://" in target:
                continue
            resolved = (md.parent / target).resolve()
            assert resolved.exists(), f"{md.name} links missing {target}"


def test_readme_test_commands_match_roadmap():
    """README's tier-1 command stays in sync with ROADMAP's verify line."""
    roadmap = (REPO / "ROADMAP.md").read_text()
    assert "python -m pytest -x -q" in README
    assert "python -m pytest -x -q" in roadmap
    assert 'not slow' in README  # fast tier documented
