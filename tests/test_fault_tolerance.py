"""Fault tolerance: straggler detection, elastic re-mesh, compression,
and crash/resume through the real train driver."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import compression, fault_tolerance as ft, sharding


def test_straggler_detection():
    mon = ft.StragglerMonitor(num_hosts=4)
    for step in range(16):
        for h in range(4):
            mon.end_step(h, wall_s=1.0 + (3.0 if h == 2 and step > 7 else 0.0))
    assert mon.stragglers() == [2]


def test_no_false_positives_on_uniform_times():
    mon = ft.StragglerMonitor(num_hosts=4)
    rng = np.random.default_rng(0)
    for _ in range(16):
        for h in range(4):
            mon.end_step(h, wall_s=1.0 + rng.normal() * 0.02)
    assert mon.stragglers() == []


def test_shrink_mesh_preserves_model_dim():
    devs = jax.devices() * 8  # fake an 8-device pool from the 1 CPU
    mesh = ft.shrink_mesh(failed_hosts={1}, hosts_per_pod=2, model=2,
                          devices=devs)
    assert mesh.shape["model"] == 2
    assert mesh.shape["data"] == 3  # (8 - 2 failed) / model 2


def test_compression_roundtrip_error_small():
    x = jax.random.normal(jax.random.key(0), (1000,)) * 0.01
    err = float(compression.quantization_error(x))
    assert err < 0.01


def test_compression_handles_outliers_per_block():
    x = jnp.concatenate([
        jax.random.normal(jax.random.key(1), (256,)) * 1e-4,
        jax.random.normal(jax.random.key(2), (256,)) * 10.0,
    ])
    # per-block scaling keeps the small-magnitude block accurate
    q, s, meta = compression.compress(x)
    back = compression.decompress(q, s, meta)
    small_err = float(jnp.linalg.norm(back[:256] - x[:256]) / jnp.linalg.norm(x[:256]))
    assert small_err < 0.01


def test_compressed_psum_single_group_is_identity():
    mesh = sharding.make_mesh((1,), ("pod",))
    x = jax.random.normal(jax.random.key(3), (300,))

    def f(v):
        return sharding.shard_map(
            lambda a: compression.compressed_psum(a, "pod"), mesh=mesh,
            in_specs=jax.sharding.PartitionSpec(),
            out_specs=jax.sharding.PartitionSpec(),
            check_vma=False,
        )(v)

    out = jax.jit(f)(x)
    # int8 block quantisation: |err| <= absmax/254 ~= 0.015 for N(0,1)
    np.testing.assert_allclose(out, x, atol=0.02, rtol=0.02)


def test_train_driver_crash_resume(tmp_path):
    """Train 6 steps with ckpt_every=3, 'crash', resume, and verify the
    resumed run continues from the checkpointed step deterministically."""
    from repro.launch.train import train

    _, losses_full = train("smollm_135m", steps=6, batch=2, seq=32,
                           ckpt_dir=str(tmp_path / "a"), ckpt_every=3,
                           log_every=100)
    # crash after 3 steps (simulated by only running 3), then resume to 6
    _, l1 = train("smollm_135m", steps=3, batch=2, seq=32,
                  ckpt_dir=str(tmp_path / "b"), ckpt_every=3, log_every=100)
    _, l2 = train("smollm_135m", steps=6, batch=2, seq=32,
                  ckpt_dir=str(tmp_path / "b"), ckpt_every=3, log_every=100)
    # resumed run must produce the same final-loss trajectory as uninterrupted
    np.testing.assert_allclose(l2[-1], losses_full[-1], rtol=1e-4)
