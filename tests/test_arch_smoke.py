"""Deliverable (f): per-architecture smoke tests.

Every assigned arch instantiates a REDUCED same-family config and runs one
forward + one train step on CPU, asserting output shapes and no NaNs. The
full configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — see launch/dryrun.py.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, list_archs, reduced
from repro.models import lm
from repro.models.train import make_train_step

ARCHS = list_archs()


def _batch(cfg, key, b=2, s=16):
    if cfg.modality == "audio":
        toks = jax.random.randint(key, (b, s, cfg.num_codebooks), 0, cfg.vocab)
    else:
        toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.modality == "image":
        batch["patch_embeds"] = jnp.zeros((b, s, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced(get_arch(arch))
    params = lm.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))
    logits, aux = jax.jit(lambda p, t: lm.forward(p, t, cfg,
                                                  patch_embeds=batch.get("patch_embeds")))(
        params, batch["tokens"]
    )
    b, s = batch["tokens"].shape[:2]
    if cfg.modality == "audio":
        assert logits.shape == (b, s, cfg.num_codebooks, cfg.vocab)
    else:
        assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_no_nans(arch):
    cfg = reduced(get_arch(arch))
    params = lm.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))
    opt_init, step = make_train_step(cfg)
    params2, _, metrics = jax.jit(step)(params, opt_init(params), batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert metrics["loss"] > 0
    nan_leaves = [
        p for p in jax.tree.leaves(params2)
        if bool(jnp.any(jnp.isnan(p.astype(jnp.float32))))
    ]
    assert not nan_leaves


@pytest.mark.parametrize("arch", ARCHS)
def test_exact_assigned_config_values(arch):
    """The full (non-reduced) configs carry the exact assigned numbers."""
    cfg = get_arch(arch)
    expected = {
        "llama3_405b": (126, 16384, 128, 8, 53248, 128256),
        "smollm_135m": (30, 576, 9, 3, 1536, 49152),
        "starcoder2_3b": (30, 3072, 24, 2, 12288, 49152),
        "qwen3_32b": (64, 5120, 64, 8, 25600, 151936),
        "musicgen_medium": (48, 1536, 24, 24, 6144, 2048),
        "pixtral_12b": (40, 5120, 32, 8, 14336, 131072),
        "mixtral_8x7b": (32, 4096, 32, 8, 14336, 32000),
        "qwen3_moe_235b_a22b": (94, 4096, 64, 4, 1536, 151936),
        "mamba2_2p7b": (64, 2560, 0, 0, 0, 50280),
        "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected


def test_param_counts_in_expected_range():
    """param_count() should land near the named sizes."""
    for arch, lo, hi in [
        ("llama3_405b", 380e9, 430e9),
        ("smollm_135m", 0.12e9, 0.15e9),
        ("starcoder2_3b", 2.5e9, 3.5e9),
        ("mixtral_8x7b", 42e9, 50e9),
        ("qwen3_moe_235b_a22b", 210e9, 250e9),
        ("mamba2_2p7b", 2.2e9, 3.0e9),
        ("zamba2_7b", 6e9, 8.5e9),
    ]:
        n = get_arch(arch).param_count()
        assert lo < n < hi, (arch, n)


def test_moe_active_params():
    cfg = get_arch("qwen3_moe_235b_a22b")
    assert cfg.active_param_count() < 0.15 * cfg.param_count()
