"""Checkpointer (atomicity, resume, GC) + data pipeline determinism."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpointer as ckpt
from repro.configs import get_arch, reduced
from repro.data import pipeline


def _tree(v=0.0):
    return {"a": jnp.full((3, 2), v), "b": {"c": jnp.full((4,), v + 1)}}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree(3.0)
    ckpt.save(tmp_path, 7, tree, extra={"step": 7})
    restored, extra = ckpt.restore(tmp_path, 7, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(a, b)
    assert extra == {"step": 7}


def test_latest_step_ignores_partial_writes(tmp_path):
    ckpt.save(tmp_path, 1, _tree())
    ckpt.save(tmp_path, 5, _tree())
    # simulate a crashed write: tmp dir + committed dir without manifest
    (tmp_path / "step_9.tmp").mkdir()
    (tmp_path / "step_8").mkdir()
    assert ckpt.latest_step(tmp_path) == 5


def test_gc_keeps_last_k(tmp_path):
    for s in range(6):
        ckpt.save(tmp_path, s, _tree(), keep=3)
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir()
                   if p.name.startswith("step_"))
    assert steps == [3, 4, 5]


def test_structure_mismatch_rejected(tmp_path):
    ckpt.save(tmp_path, 0, _tree())
    import pytest
    with pytest.raises(AssertionError):
        ckpt.restore(tmp_path, 0, {"different": jnp.zeros((1,))})


# ------------------------------ data ------------------------------------
def test_batches_deterministic():
    cfg = reduced(get_arch("smollm_135m"))
    dc = pipeline.DataConfig(seq_len=16, global_batch=8, vocab=cfg.vocab, seed=1)
    b1 = pipeline.synthetic_batch(cfg, dc, step=3)
    b2 = pipeline.synthetic_batch(cfg, dc, step=3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = pipeline.synthetic_batch(cfg, dc, step=4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_shards_partition_global_batch():
    cfg = reduced(get_arch("smollm_135m"))
    dc = pipeline.DataConfig(seq_len=8, global_batch=8, vocab=cfg.vocab)
    full = pipeline.synthetic_batch(cfg, dc, step=0, shard_id=0, num_shards=1)
    parts = [
        pipeline.synthetic_batch(cfg, dc, step=0, shard_id=i, num_shards=4)
        for i in range(4)
    ]
    assert all(p["tokens"].shape == (2, 8) for p in parts)
    # shards are disjoint deterministic functions of (step, shard)
    again = pipeline.synthetic_batch(cfg, dc, step=0, shard_id=2, num_shards=4)
    np.testing.assert_array_equal(parts[2]["tokens"], again["tokens"])
    del full


def test_labels_are_shifted_tokens():
    cfg = reduced(get_arch("smollm_135m"))
    dc = pipeline.DataConfig(seq_len=8, global_batch=2, vocab=cfg.vocab)
    b = pipeline.synthetic_batch(cfg, dc, step=0)
    np.testing.assert_array_equal(
        np.asarray(b["labels"])[:, :-1], np.asarray(b["tokens"])[:, 1:]
    )
