"""Random/Greedy baseline policies (paper §IV.A)."""
import jax
import jax.numpy as jnp

from repro.core import baselines, env as env_lib
from repro.core.types import Action


def test_greedy_picks_nearest_compatible():
    p = env_lib.default_params(num_eds=3, num_models=3)
    state = env_lib.reset(jax.random.key(0), p)
    obs = env_lib.observe(state, p)
    act = baselines.greedy_policy(None, obs, p)
    compat = state.cache[:, state.task.mu].T  # (M, N)
    dist = jnp.linalg.norm(
        state.es_pos[None] - state.ed_pos[:, None], axis=-1
    )
    for m in range(p.num_eds):
        if float(compat[m].max()) > 0.5:
            cands = jnp.where(compat[m] > 0.5, dist[m], jnp.inf)
            assert int(act.target[m]) == int(jnp.argmin(cands)) + 1
            assert float(act.eta[m]) == 1.0  # paper: fixed ratio 1.0
        else:
            assert int(act.target[m]) == 0  # local fallback
    assert bool(jnp.all(act.beta == 0))


def test_random_policy_in_bounds():
    p = env_lib.default_params(num_eds=16, num_models=3)
    state = env_lib.reset(jax.random.key(1), p)
    obs = env_lib.observe(state, p)
    act = baselines.random_policy(jax.random.key(2), obs, p)
    assert bool(jnp.all((act.target >= 0) & (act.target <= p.num_ess)))
    assert bool(jnp.all((act.eta >= 0) & (act.eta <= 1)))
