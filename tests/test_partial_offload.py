"""Env <-> router cross-check for the eq. 16 action space.

The training environment (``core.env.step``) and the serving oracle
(``core.router.ModelAwareRouter``) price the SAME paper equations —
eq. 3 local share, eq. 5 uplink, eq. 7/8 model switch, eq. 9 edge
compute, eq. 13 max-overlap — from two different codebases. This module
pins them against each other for full ``(target, eta, beta)`` action
sequences: with power-of-two task sizes, densities and ratios every
product in both pipelines is exact, so the two latencies must agree
BITWISE (the only rounding happens in the shared divisions, which see
identical operands). Residency/LRU dynamics are compared step for step
along the way.

The mapping between the two worlds:

* ``x * rho`` (env cycles)  ==  ``gen_tokens * decode_flops_per_token``
  (router work) — the test picks ``gen = x * rho / ftok`` exactly;
* the env's per-step Shannon rate becomes the server's ``uplink_bps``
  (M = 1, so the contention divisor is 1 and the rate is static);
* the env has no queue backlog — the oracle's queues are zeroed before
  each pricing (commit effects are tested separately in
  ``tests/test_batch_router.py``).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import costs, env
from repro.core.catalog import CatalogEntry
from repro.core.router import EdgeServer, ModelAwareRouter, Request
from repro.core.types import Action
from repro.workloads.simulate import request_energy_j

# power-of-two world: every product below is exact in f32 AND f64
_X_BITS = [2.0 ** 23, 2.0 ** 22, 2.0 ** 24]       # task sizes
_RHO = [2.0 ** 6, 2.0 ** 5, 2.0 ** 7]             # compute densities
_FTOK = 2.0 ** 20                                  # decode FLOPs/token
_MODEL_BITS = (2.0 ** 30, 2.0 ** 31, 2.0 ** 29)    # switch payloads
_F_ES = 2.0 ** 33
_F_ED = 2.0 ** 31
_ETAS = [1.0, 0.5, 0.25, 0.75]


def _setup(num_ess=2, cache=((0, 1), (1, 2))):
    """One-ED env + the equivalent oracle fleet, residency synced."""
    p = env.default_params(num_eds=1, num_models=3, num_ess=num_ess)
    p = p._replace(model_bits=_MODEL_BITS, f_es=_F_ES,
                   deadline=(64.0,) * 3)
    s = env.reset(jax.random.key(0), p)
    cache_arr = np.zeros((num_ess, 3), np.float64)
    for n, models in enumerate(cache):
        cache_arr[n, list(models)] = 1.0
    s = s._replace(
        f_ed=jnp.full((1,), _F_ED, jnp.float64),
        cache=jnp.asarray(cache_arr),
        last_use=jnp.zeros((num_ess, 3), jnp.int32),
    )
    # the env's per-(ED, ES) Shannon rate IS the server's uplink
    dist = jnp.linalg.norm(
        s.ed_pos[0].astype(jnp.float64) - s.es_pos.astype(jnp.float64),
        axis=-1)
    gain = costs.channel_gain(dist, p.pathloss_ref, p.pathloss_exp)
    rates = costs.shannon_rate(p.bandwidth_hz, p.tx_power_w, gain,
                               p.noise_w_per_hz)
    catalog = [
        CatalogEntry(k, f"m{k}", "f", 1, _MODEL_BITS[k], _FTOK)
        for k in range(3)
    ]
    servers = [
        EdgeServer(name=f"es{n}", flops_per_s=_F_ES, cache_slots=2,
                   uplink_bps=float(rates[n]), backhaul_bps=p.backhaul_bps,
                   resident=list(cache[n]))
        for n in range(num_ess)
    ]
    return p, s, catalog, servers


def _env_step(p, s, *, model, x, rho, target, eta, beta):
    """Run one eager x64 env step on a crafted task/action pair."""
    s = s._replace(task=s.task._replace(
        mu=jnp.asarray([model], jnp.int32),
        x_bits=jnp.asarray([x], jnp.float64),
        rho=jnp.asarray([rho], jnp.float64),
    ))
    act = Action(target=jnp.asarray([target], jnp.int32),
                 eta=jnp.asarray([eta], jnp.float64),
                 beta=jnp.asarray([1.0 if beta else 0.0], jnp.float64))
    s2, _, out, _ = env.step(s, act, p)
    # keep the crafted-task loop going: step resamples tasks, positions
    # and f_ed persist
    return s2, out


# one action per step: (model, x, rho, es target (1-based), eta, beta)
_SEQUENCE = [
    (0, _X_BITS[0], _RHO[0], 1, 0.5, True),    # hit on es0 (model 0)
    (1, _X_BITS[1], _RHO[1], 1, 1.0, True),    # hit on es0 (model 1)
    (2, _X_BITS[2], _RHO[2], 1, 0.25, True),   # miss -> download, evict
    (2, _X_BITS[0], _RHO[1], 2, 0.75, True),   # hit on es1 (model 2)
    (0, _X_BITS[1], _RHO[2], 2, 0.5, True),    # miss -> download on es1
    (1, _X_BITS[2], _RHO[0], 1, 1.0, True),    # post-eviction revisit
]


@pytest.mark.parametrize("local", [False, True])
def test_env_latency_bitmatches_oracle_sequence(local):
    """Env step latencies == oracle partial-offload pricing, bit for bit,
    along a fixed (target, eta, beta) sequence; residency/LRU evolve in
    lockstep. ``local`` toggles the eq. 3 device share (eq. 13 max)."""
    with enable_x64():
        p, s, catalog, servers = _setup()
        router = ModelAwareRouter(servers, catalog, policy="actor",
                                  actor=None)
        for step_i, (m, x, rho, tgt, eta, beta) in enumerate(_SEQUENCE):
            s, out = _env_step(p, s, model=m, x=x, rho=rho, target=tgt,
                               eta=eta, beta=beta)
            assert float(out.failed_compat[0]) == 0.0
            # oracle prices the same action against a clean queue
            for srv in router.servers:
                srv.queue_tokens = 0.0
            router.actor = lambda obs, lats, _t=tgt: _t - 1
            req = Request(
                m, x, x * rho / _FTOK, eta=eta, beta=beta,
                local_flops_per_s=_F_ED if local else None,
            )
            choice, lat = router.route(req)
            assert choice == tgt - 1, step_i
            if local:
                np.testing.assert_array_equal(
                    lat, float(out.latency[0]), err_msg=f"step {step_i}")
            else:  # edge-only pricing: the env's eq. 13 max still applies
                t_loc = costs.local_latency(x, eta, rho, _F_ED)
                np.testing.assert_array_equal(
                    max(float(t_loc), lat), float(out.latency[0]),
                    err_msg=f"step {step_i}")
            # residency dynamics track bit for bit (download + LRU evict)
            cache = np.asarray(s.cache)
            for n, srv in enumerate(router.servers):
                assert set(srv.resident) == set(np.nonzero(cache[n])[0]), \
                    f"step {step_i} server {n}"


def test_env_energy_matches_equation_composition():
    """Env step energy == the eta-aware eq. 4/6/8/10 composition (the
    corrected variants), term for term through ``core.costs``."""
    with enable_x64():
        p, s, _, servers = _setup()
        m, x, rho, tgt, eta = 2, _X_BITS[2], _RHO[2], 1, 0.25
        dist = float(np.linalg.norm(
            np.asarray(s.ed_pos[0], np.float64)
            - np.asarray(s.es_pos[tgt - 1], np.float64)))
        gain = costs.channel_gain(dist, p.pathloss_ref, p.pathloss_exp)
        rate = costs.shannon_rate(p.bandwidth_hz, p.tx_power_w, gain,
                                  p.noise_w_per_hz)
        _, out = _env_step(p, s, model=m, x=x, rho=rho, target=tgt,
                           eta=eta, beta=True)
        t_trans = costs.trans_latency(x, eta, rate)
        t_switch = costs.switch_latency(_MODEL_BITS[m], p.backhaul_bps)
        e_edge = costs.edge_total_energy(
            costs.trans_energy(p.tx_power_w, t_trans),
            costs.switch_energy(p.backhaul_power_w, t_switch),
            costs.edge_energy_corrected(x, eta, rho, p.kappa_es, p.f_es),
        )
        e_local = costs.local_energy_corrected(x, eta, rho, p.kappa_ed,
                                               _F_ED)
        np.testing.assert_array_equal(
            float(costs.total_energy(e_local, e_edge, False)),
            float(out.energy[0]))


def test_refused_miss_is_env_failed_compat_and_oracle_inf():
    """beta = False on a residency miss: the env flags failed_compat,
    the oracle prices that candidate +inf (refusal re-prices against
    resident-only columns — the shared eq. 16 semantics)."""
    with enable_x64():
        p, s, catalog, servers = _setup()
        m, x, rho, tgt = 2, _X_BITS[2], _RHO[2], 1   # model 2 not on es0
        _, out = _env_step(p, s, model=m, x=x, rho=rho, target=tgt,
                           eta=0.5, beta=False)
        assert float(out.failed_compat[0]) == 1.0
        assert float(out.completed[0]) == 0.0
        router = ModelAwareRouter(servers, catalog)
        req = Request(m, x, x * rho / _FTOK, eta=0.5, beta=False)
        assert np.isinf(router._candidate_latency(router.servers[0], req))
        # the refused fleet re-prices resident-only: es1 holds model 2
        choice, lat = router.route(req)
        assert choice == 1 and np.isfinite(lat)
        # a hit under beta = False completes on both sides
        s2, out2 = _env_step(p, s, model=0, x=x, rho=rho, target=1,
                             eta=0.5, beta=False)
        assert float(out2.failed_compat[0]) == 0.0
        assert float(out2.completed[0]) == 1.0


def test_request_energy_eta_scales_edge_share():
    """The serving-side energy metric scales eq. 6/10 with eta and keeps
    the eq. 8 hit gate — the eta = 1 column equals the eta-free call."""
    from repro.core import batch_router as br
    from repro.core.catalog import build_catalog

    with enable_x64():
        cat = build_catalog(["smollm_135m", "starcoder2_3b"])
        fleet = [EdgeServer(name="es0", flops_per_s=1e14, cache_slots=2,
                            uplink_bps=1e8, backhaul_bps=1e9, resident=[0])]
        params, state = br.fleet_from_servers(fleet, cat)
        reqs = br.RequestBatch(
            model=jnp.asarray([0, 1], jnp.int32),
            prompt_bits=jnp.asarray([2.0 ** 20, 2.0 ** 21]),
            gen_tokens=jnp.asarray([16.0, 32.0]),
        )
        _, out = br.route_batch(params, state, reqs)
        base = request_energy_j(params, reqs, out)
        ones = request_energy_j(
            params, reqs._replace(eta=jnp.asarray([1.0, 1.0])), out)
        np.testing.assert_array_equal(base, ones)
        half = request_energy_j(
            params, reqs._replace(eta=jnp.asarray([0.5, 0.5])), out)
        # transmission + compute halve; the eq. 8 switch term does not
        model = np.asarray(reqs.model)
        t_switch = np.where(
            np.asarray(out.hit), 0.0,
            np.asarray(params.size_bits)[model]
            / np.asarray(params.backhaul_bps)[np.asarray(out.choice)])
        e_switch = 2.0 * t_switch
        np.testing.assert_allclose(
            half - e_switch, (base - e_switch) / 2.0, rtol=1e-9)
