"""Replay buffer + AdamW unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import replay
from repro.optim import adamw
from repro.optim.adamw import apply_updates, clip_by_global_norm, cosine_schedule


def test_replay_wraparound_and_sampling():
    buf = replay.init(8, {"x": jnp.zeros((2,))})
    for i in range(6):
        buf = replay.add_batch(buf, {"x": jnp.full((3, 2), float(i))}, 3)
    assert int(buf.size) == 8
    assert int(buf.ptr) == 18 % 8
    batch = replay.sample(buf, jax.random.key(0), 64)
    assert batch["x"].shape == (64, 2)
    # all sampled values must be among the last writes still in the buffer
    assert bool(jnp.all(batch["x"] >= 0))


def test_replay_preserves_recent_items():
    buf = replay.init(4, {"x": jnp.zeros(())})
    buf = replay.add_batch(buf, {"x": jnp.arange(6.0)}, 6)
    # capacity 4, wrote 0..5 -> buffer holds {4, 5, 2, 3}
    vals = set(np.asarray(buf.data["x"]).tolist())
    assert vals == {2.0, 3.0, 4.0, 5.0}


def test_adamw_converges_on_quadratic():
    init_fn, upd_fn = adamw(0.1)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_fn(params)
    for _ in range(200):
        grads = jax.tree.map(lambda w: 2 * w, params)  # d/dw w^2
        updates, state = upd_fn(grads, state, params)
        params = apply_updates(params, updates)
    np.testing.assert_allclose(params["w"], jnp.zeros(2), atol=1e-2)


def test_adamw_bf16_moments_track_fp32():
    init_fn, upd_fn = adamw(0.01, moment_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = init_fn(params)
    assert state.mu["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.ones((4,), jnp.bfloat16)}
    updates, state = upd_fn(grads, state, params)
    assert updates["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(updates["w"].astype(jnp.float32))))


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}  # norm 5
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)


def test_cosine_schedule_warmup_and_floor():
    sched = cosine_schedule(1e-3, warmup=10, total=100, floor=0.1)
    assert float(sched(0)) < float(sched(9)) <= 1e-3 * (1 + 1e-6)
    assert float(sched(100)) >= 0.1 * 1e-3 - 1e-9
