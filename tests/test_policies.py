"""Serving-policy subsystem (core/policies.py + the drain-aware greedy).

Pins the three contracts the trained-actor serving path rests on:

* the observation bridge reproduces ``core.env.observe``'s eq. 16 layout
  field for field (C in {1, 2} cell topologies);
* an actor checkpoint round-trips through ``checkpoint.checkpointer``
  and routes batches deterministically, with the scalar oracle
  reproducing the stream bit for bit given the same action sequence;
* the drain-aware greedy matches its scalar-oracle twin on both scan
  paths, degenerates to plain greedy without drain, and beats plain
  greedy on a bursty-arrival fixture.
"""
import copy

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import batch_router as br
from repro.core import env as env_lib, maddpg, policies
from repro.core.catalog import build_catalog, env_params_from_catalog
from repro.core.router import CLOUD_CELL, EdgeServer, ModelAwareRouter, Request

CATALOG = build_catalog(
    ["smollm_135m", "starcoder2_3b", "mamba2_2p7b", "musicgen_medium"]
)


# ---------------------------------------------------------------------------
# observation bridge vs core.env
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cells", [1, 2])
def test_obs_dim_matches_env(cells):
    p = env_lib.default_params(num_eds=5, num_models=3, num_ess=4,
                               num_cells=cells)
    assert policies.obs_dim(policies.spec_from_env(p)) == env_lib.obs_dim(p)


@pytest.mark.parametrize("cells", [1, 2])
def test_build_obs_matches_env_observe(cells):
    """The builder reproduces every agent's eq. 16 row exactly, given the
    env's own state — including the cell-masked compat columns."""
    p = env_lib.default_params(num_eds=4, num_models=3, num_ess=4,
                               num_cells=cells)
    state = env_lib.reset(jax.random.key(3), p)
    want = np.asarray(env_lib.observe(state, p))
    spec = policies.spec_from_env(p)
    es_cell = np.asarray(env_lib.es_cell(p))
    ed_cell = np.asarray(env_lib.ed_cell(p))
    for m in range(p.num_eds):
        mu = int(state.task.mu[m])
        compat = np.asarray(state.cache)[:, mu] * (es_cell == ed_cell[m])
        got = policies.build_obs(
            spec,
            model=jnp.int32(mu),
            x_bits=state.task.x_bits[m],
            rho=state.task.rho[m],
            f_es=jnp.full((p.num_ess,), p.f_es),
            compat=jnp.asarray(compat, jnp.float32),
            ed_pos=state.ed_pos[m],
            es_pos=state.es_pos,
            cc_pos=state.cc_pos,
            f_ed=state.f_ed[m],
        )
        np.testing.assert_allclose(np.asarray(got), want[m], rtol=1e-6,
                                   err_msg=f"agent {m}")


def test_cell_index_map_single_cell_trained():
    """num_cells=1 actor on a C-cell fleet: row c gathers cell c's
    servers; the cloud column is never offered."""
    spec = policies.spec_from_env(
        env_lib.default_params(num_eds=2, num_models=4, num_ess=3)
    )
    fleet_cell = np.array([0, 0, 0, 1, 1, 1, CLOUD_CELL], np.int32)
    rows, col_cell = policies.cell_index_map(spec, fleet_cell)
    np.testing.assert_array_equal(rows, [[0, 1, 2], [3, 4, 5]])
    np.testing.assert_array_equal(col_cell, [[0, 0, 0], [1, 1, 1]])


def test_cell_index_map_matched_topology():
    """num_cells=C actor on the matching fleet: every row is the full
    edge fleet (compat is cell-masked downstream, as in training)."""
    p = env_lib.default_params(num_eds=4, num_models=3, num_ess=4,
                               num_cells=2)
    spec = policies.spec_from_env(p)
    fleet_cell = np.asarray(env_lib.es_cell(p))  # round-robin 0,1,0,1
    rows, col_cell = policies.cell_index_map(spec, fleet_cell)
    np.testing.assert_array_equal(rows, [[0, 1, 2, 3]] * 2)
    np.testing.assert_array_equal(col_cell, [fleet_cell] * 2)
    # env-style compat mask falls out of col_cell == request cell
    np.testing.assert_array_equal(col_cell[0] == 0, [True, False] * 2)
    np.testing.assert_array_equal(col_cell[1] == 1, [False, True] * 2)


def test_cell_index_map_rejects_mismatched_geometry():
    spec = policies.spec_from_env(
        env_lib.default_params(num_eds=2, num_models=4, num_ess=3)
    )
    with pytest.raises(ValueError, match="2 edge servers"):
        policies.cell_index_map(spec, np.array([0, 0, 1, 1], np.int32))
    with pytest.raises(ValueError, match="cannot map"):
        policies.cell_index_map(
            spec._replace(num_cells=3), np.array([0, 0, 1, 1], np.int32)
        )


# ---------------------------------------------------------------------------
# actor checkpoint round-trip through the batched router
# ---------------------------------------------------------------------------
def _multicell_fleet(n_cells, per_cell, drain_rate=0.0):
    fleet = [
        EdgeServer(
            name=f"c{c}-es{i}", flops_per_s=197e12, cache_slots=2,
            uplink_bps=1e8, backhaul_bps=1e9,
            resident=[(2 * i + j) % len(CATALOG) for j in range(2)],
            cell=c, drain_rate=drain_rate,
        )
        for c in range(n_cells)
        for i in range(per_cell)
    ]
    fleet.append(EdgeServer(
        name="cloud", flops_per_s=2e15, cache_slots=len(CATALOG),
        uplink_bps=5e7, backhaul_bps=1e9,
        resident=list(range(len(CATALOG))), cell=CLOUD_CELL,
    ))
    return fleet


def test_actor_checkpoint_roundtrip_routes_deterministically(tmp_path):
    """save -> restore -> route: parameters survive bit-exactly, routing
    is deterministic, and the scalar oracle replaying the SAME action
    sequence reproduces latencies and fleet state bit for bit."""
    with enable_x64():
        p = env_params_from_catalog(CATALOG, num_eds=4, num_ess=3)
        cfg = maddpg.AlgoConfig(hidden=32)
        ts = maddpg.init_state(jax.random.key(0), p, cfg)
        policies.save_actor_checkpoint(tmp_path, ts.actor, p, cfg)

        restored, spec, extra = policies.load_actor_checkpoint(tmp_path)
        for a, b in zip(jax.tree.leaves(ts.actor), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert spec == policies.spec_from_env(p)
        assert extra["model_aware"] is True

        fleet = _multicell_fleet(2, 3)
        params, state = br.fleet_from_servers(fleet, CATALOG)
        policy = policies.load_actor_policy(tmp_path, params)

        rng = np.random.default_rng(5)
        n = 150
        reqs = br.RequestBatch(
            model=jnp.asarray(rng.integers(0, len(CATALOG), n), jnp.int32),
            prompt_bits=jnp.asarray(rng.uniform(1e5, 1e6, n), jnp.float64),
            gen_tokens=jnp.asarray(rng.integers(1, 64, n), jnp.float64),
            cell=jnp.asarray(rng.integers(0, 2, n), jnp.int32),
        )
        _, out1 = br.route_batch(params, state, reqs, policy=policy)
        state2, out2 = br.route_batch(params, state, reqs, policy=policy)
        np.testing.assert_array_equal(np.asarray(out1.choice),
                                      np.asarray(out2.choice))
        np.testing.assert_array_equal(np.asarray(out1.latency),
                                      np.asarray(out2.latency))

        # the actor only ever places requests on in-cell edge servers
        srv_cell = np.array([s.cell for s in fleet])
        choices = np.asarray(out2.choice)
        np.testing.assert_array_equal(srv_cell[choices],
                                      np.asarray(reqs.cell))

        # scalar oracle, same action sequence -> same latencies/state
        script = iter(choices.tolist())
        router = ModelAwareRouter(copy.deepcopy(fleet), CATALOG,
                                  policy="actor",
                                  actor=lambda obs, lats: next(script))
        sc = [router.route(Request(int(m), float(b), int(t), cell=int(c)))
              for m, b, t, c in zip(np.asarray(reqs.model),
                                    np.asarray(reqs.prompt_bits),
                                    np.asarray(reqs.gen_tokens),
                                    np.asarray(reqs.cell))]
        np.testing.assert_array_equal(choices, [c for c, _ in sc])
        np.testing.assert_allclose(np.asarray(out2.latency),
                                   [l for _, l in sc], rtol=1e-12, atol=0.0)
        resident = np.asarray(state2.resident)
        for i, srv in enumerate(router.servers):
            assert set(np.nonzero(resident[i])[0]) == set(srv.resident), i
        np.testing.assert_allclose(np.asarray(state2.queue_tokens),
                                   [s.queue_tokens for s in router.servers],
                                   rtol=1e-12)


def test_actor_policy_chunked_matches_scan(tmp_path):
    """The ctx-threaded chunked path reproduces the single-scan actor
    decisions (the PolicyCtx plumbing is path-invariant)."""
    p = env_params_from_catalog(CATALOG, num_eds=4, num_ess=3)
    cfg = maddpg.AlgoConfig(hidden=32)
    ts = maddpg.init_state(jax.random.key(1), p, cfg)
    policies.save_actor_checkpoint(tmp_path, ts.actor, p, cfg)

    fleet = _multicell_fleet(2, 3, drain_rate=1e4)
    params, state = br.fleet_from_servers(fleet, CATALOG)
    policy = policies.load_actor_policy(tmp_path, params)

    rng = np.random.default_rng(6)
    n = 130
    reqs = br.RequestBatch(
        model=jnp.asarray(rng.integers(0, len(CATALOG), n), jnp.int32),
        prompt_bits=jnp.asarray(rng.uniform(1e5, 1e6, n), jnp.float32),
        gen_tokens=jnp.asarray(rng.integers(1, 64, n), jnp.float32),
        cell=jnp.asarray(rng.integers(0, 2, n), jnp.int32),
        arrival_s=jnp.asarray(np.cumsum(rng.exponential(0.01, n)),
                              jnp.float32),
    )
    s0, o0 = br.route_batch(params, state, reqs, policy=policy)
    s1, o1 = br.route_batch(params, state, reqs, policy=policy, chunk=32)
    np.testing.assert_array_equal(np.asarray(o0.choice),
                                  np.asarray(o1.choice))
    np.testing.assert_array_equal(np.asarray(s0.resident),
                                  np.asarray(s1.resident))
    np.testing.assert_allclose(np.asarray(s0.queue_tokens),
                               np.asarray(s1.queue_tokens), rtol=1e-6)


# ---------------------------------------------------------------------------
# drain-aware greedy
# ---------------------------------------------------------------------------
def _random_drain_fleet(rng, n_servers):
    return [
        EdgeServer(
            name=f"es{i}",
            flops_per_s=float(rng.uniform(5e13, 2e14)),
            cache_slots=2,
            uplink_bps=float(rng.uniform(5e7, 2e8)),
            backhaul_bps=float(rng.uniform(5e8, 2e9)),
            resident=list(rng.choice(len(CATALOG), size=2, replace=False)),
            drain_rate=float(rng.uniform(0.0, 1e5)),
        )
        for i in range(n_servers)
    ]


@pytest.mark.parametrize("chunk", [None, 64])
def test_drain_policy_matches_scalar_oracle(chunk):
    """policy='drain' on both batched paths == the scalar oracle's drain
    policy, over random drain rates and Poisson-ish arrivals."""
    rng = np.random.default_rng(17)
    servers = _random_drain_fleet(rng, 5)
    n = 200
    models = rng.integers(0, len(CATALOG), n)
    bits = rng.uniform(1e5, 1e6, n)
    toks = rng.integers(1, 64, n)
    arrivals = np.cumsum(rng.exponential(0.01, n))

    router = ModelAwareRouter(copy.deepcopy(servers), CATALOG,
                              policy="drain")
    sc_choice = [
        router.route(Request(int(m), float(b), int(t),
                             arrival_s=float(a)))[0]
        for m, b, t, a in zip(models, bits, toks, arrivals)
    ]
    params, state = br.fleet_from_servers(servers, CATALOG)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
        arrival_s=jnp.asarray(arrivals, jnp.float32),
    )
    state, out = br.route_batch(params, state, reqs, policy="drain",
                                chunk=chunk)
    np.testing.assert_array_equal(np.asarray(out.choice), sc_choice)
    resident = np.asarray(state.resident)
    for i, srv in enumerate(router.servers):
        assert set(np.nonzero(resident[i])[0]) == set(srv.resident), i
    np.testing.assert_allclose(np.asarray(state.queue_tokens),
                               [s.queue_tokens for s in router.servers],
                               rtol=1e-5)


def test_drain_degenerates_to_greedy_without_drain():
    """drain_rate == 0 everywhere: the discounted score equals eq. 11 and
    the two policies route identically."""
    rng = np.random.default_rng(23)
    servers = _random_drain_fleet(rng, 4)
    for s in servers:
        s.drain_rate = 0.0
    n = 150
    reqs = br.RequestBatch(
        model=jnp.asarray(rng.integers(0, len(CATALOG), n), jnp.int32),
        prompt_bits=jnp.asarray(rng.uniform(1e5, 1e6, n), jnp.float32),
        gen_tokens=jnp.asarray(rng.integers(1, 64, n), jnp.float32),
    )
    params, state = br.fleet_from_servers(servers, CATALOG)
    _, o_greedy = br.route_batch(params, state, reqs, policy="greedy")
    _, o_drain = br.route_batch(params, state, reqs, policy="drain")
    np.testing.assert_array_equal(np.asarray(o_greedy.choice),
                                  np.asarray(o_drain.choice))


def _bursty_fixture():
    """Hand-built burst pattern where drain awareness pays: server A is
    fast and drains its in-burst backlog away (its ``drain_rate`` is a
    multiple of its own decode throughput); server B is 10x slower and
    never drains. Greedy prices A's transient backlog at face value and
    spills onto B mid-burst, paying B's slow service; the drain-aware
    policy knows A's backlog melts and keeps the burst on A."""
    model = 1  # starcoder2_3b: ftok ~6e9 -> A's throughput ~3e4 tok/s
    servers = [
        EdgeServer(name="A", flops_per_s=2e14, cache_slots=2,
                   uplink_bps=1e8, backhaul_bps=1e9, resident=[model],
                   drain_rate=5e5),
        EdgeServer(name="B", flops_per_s=2e13, cache_slots=2,
                   uplink_bps=1e8, backhaul_bps=1e9, resident=[model],
                   drain_rate=0.0),
    ]
    n_bursts, per_burst = 4, 80
    n = n_bursts * per_burst
    arrivals = np.repeat(np.arange(n_bursts) * 1.0, per_burst)
    reqs = br.RequestBatch(
        model=jnp.full((n,), model, jnp.int32),
        prompt_bits=jnp.full((n,), 1e5, jnp.float32),
        gen_tokens=jnp.full((n,), 500.0, jnp.float32),
        arrival_s=jnp.asarray(arrivals, jnp.float32),
    )
    return servers, reqs


def _requests_list(reqs):
    return [
        Request(int(m), float(b), int(t), arrival_s=float(a))
        for m, b, t, a in zip(np.asarray(reqs.model),
                              np.asarray(reqs.prompt_bits),
                              np.asarray(reqs.gen_tokens),
                              np.asarray(reqs.arrival_s))
    ]


def test_drain_beats_greedy_on_bursty_fixture():
    """Compared on the drain-corrected realized latency (the model-
    consistent metric — raw eq. 11 is greedy's own objective and prices
    the draining backlog with a known bias, see
    ``policies.drain_corrected_latencies``)."""
    servers, reqs = _bursty_fixture()
    params, state = br.fleet_from_servers(servers, CATALOG)
    _, o_greedy = br.route_batch(params, state, reqs, policy="greedy")
    _, o_drain = br.route_batch(params, state, reqs, policy="drain")
    # the policies genuinely diverge: greedy spills part of each burst
    # onto the slow no-drain server
    g_choice = np.asarray(o_greedy.choice)
    d_choice = np.asarray(o_drain.choice)
    assert (g_choice != d_choice).any()
    assert (g_choice == 1).sum() > (d_choice == 1).sum()

    requests = _requests_list(reqs)
    lat_greedy = np.mean(policies.drain_corrected_latencies(
        servers, CATALOG, requests, g_choice))
    lat_drain = np.mean(policies.drain_corrected_latencies(
        servers, CATALOG, requests, d_choice))
    # structural margin (greedy keeps paying B's slow undrained service),
    # not a tie-break accident
    assert lat_drain < 0.9 * lat_greedy, (lat_drain, lat_greedy)


# ---------------------------------------------------------------------------
# chunk-level actor hook: batched table scoring + drift replay
# ---------------------------------------------------------------------------
def _hooked_actor(tmp_path, seed=2):
    p = env_params_from_catalog(CATALOG, num_eds=4, num_ess=3)
    cfg = maddpg.AlgoConfig(hidden=32)
    ts = maddpg.init_state(jax.random.key(seed), p, cfg)
    policies.save_actor_checkpoint(tmp_path, ts.actor, p, cfg)
    fleet = _multicell_fleet(2, 3, drain_rate=1e4)
    params, state = br.fleet_from_servers(fleet, CATALOG)
    return policies.load_actor_policy(tmp_path, params), params, state


def test_chunk_hook_radius1_table_and_drift_flag(tmp_path):
    """The hook contract, pinned at the unit level: chunk_precompute
    prices the chunk-entry compat row plus every single-bit flip, and
    chunk_apply resolves the LIVE row against that table — exact for
    Hamming distance <= 1, flagged inexact (whole-chunk replay) for
    multi-bit drift."""
    policy, params, state = _hooked_actor(tmp_path)
    assert policy.needs_ctx and hasattr(policy, "chunk_precompute")
    m = 1
    scalars = dict(
        model=jnp.asarray([m], jnp.int32),
        prompt_bits=jnp.asarray([2e5], jnp.float32),
        gen_tokens=jnp.asarray([16.0], jnp.float32),
        flops_tok=params.decode_flops_per_token[jnp.asarray([m])],
    )
    cctx = br.ChunkPolicyCtx(params=params, resident=state.resident,
                             cell=jnp.asarray([0], jnp.int32), **scalars)
    aux = policy.chunk_precompute(cctx)
    aux_b = jax.tree.map(lambda a: a[0], aux)
    ctx = br.PolicyCtx(
        params=params, model=jnp.int32(m),
        prompt_bits=jnp.float32(2e5), gen_tokens=jnp.float32(16.0),
        flops_tok=params.decode_flops_per_token[m],
        resident=state.resident[:, m], queue=state.queue_tokens,
        cell=jnp.int32(0),
    )
    # no drift: table hit, same decision as the per-request path
    choice0, exact0 = policy.chunk_apply(aux_b, ctx)
    assert bool(exact0)
    assert int(choice0) == int(policy(None, None, None, ctx))
    # single-bit drift on an IN-CELL server: still a table hit
    flip1 = ctx._replace(resident=ctx.resident.at[0].set(~ctx.resident[0]))
    choice1, exact1 = policy.chunk_apply(aux_b, flip1)
    assert bool(exact1)
    assert int(choice1) == int(policy(None, None, None, flip1))
    # drift on an OUT-OF-CELL server is invisible through the cell mask
    flip_oc = ctx._replace(resident=ctx.resident.at[4].set(
        ~ctx.resident[4]))
    choice_oc, exact_oc = policy.chunk_apply(aux_b, flip_oc)
    assert bool(exact_oc)
    assert int(choice_oc) == int(choice0)
    # two-bit drift: outside the radius-1 table -> inexact, replay
    flip2 = flip1._replace(resident=flip1.resident.at[1].set(
        ~flip1.resident[1]))
    _, exact2 = policy.chunk_apply(aux_b, flip2)
    assert not bool(exact2)


def test_chunk_hook_forced_replay_matches_scan(tmp_path):
    """The router's whole-chunk replay path: a hook whose chunk_apply
    always reports inexact forces EVERY chunk through the serial
    per-request fallback — the stream must still match the unchunked
    scan decision for decision, state for state."""
    base, params, state = _hooked_actor(tmp_path, seed=3)

    def forced(lats, obs, queue, ctx):
        return base(lats, obs, queue, ctx)

    forced.needs_obs = False
    forced.needs_ctx = True
    forced.chunk_precompute = base.chunk_precompute
    forced.chunk_apply = lambda aux_b, ctx: (base.chunk_apply(aux_b, ctx)[0],
                                             jnp.bool_(False))

    rng = np.random.default_rng(8)
    n = 130
    reqs = br.RequestBatch(
        model=jnp.asarray(rng.integers(0, len(CATALOG), n), jnp.int32),
        prompt_bits=jnp.asarray(rng.uniform(1e5, 1e6, n), jnp.float32),
        gen_tokens=jnp.asarray(rng.integers(1, 64, n), jnp.float32),
        cell=jnp.asarray(rng.integers(0, 2, n), jnp.int32),
        arrival_s=jnp.asarray(np.cumsum(rng.exponential(0.01, n)),
                              jnp.float32),
    )
    s0, o0 = br.route_batch(params, state, reqs, policy=base)
    s1, o1 = br.route_batch(params, state, reqs, policy=forced, chunk=32)
    np.testing.assert_array_equal(np.asarray(o0.choice),
                                  np.asarray(o1.choice))
    np.testing.assert_array_equal(np.asarray(o0.hit), np.asarray(o1.hit))
    resident = np.asarray(s0.resident)
    np.testing.assert_array_equal(resident, np.asarray(s1.resident))
    # non-resident clocks are dead state (the two paths park them
    # differently); the LIVE clocks must agree exactly
    np.testing.assert_array_equal(
        np.where(resident, np.asarray(s0.last_use), 0),
        np.where(resident, np.asarray(s1.last_use), 0))
    np.testing.assert_allclose(np.asarray(s0.queue_tokens),
                               np.asarray(s1.queue_tokens), rtol=1e-6)
