"""Pallas flash-decode kernel vs oracle: positions, windows, GQA, dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode

CASES = [
    # B, S, H, KV, D, pos, window, dtype
    (2, 1024, 8, 2, 64, 1023, 0, jnp.float32),
    (2, 1024, 8, 8, 64, 500, 0, jnp.float32),
    (1, 2048, 4, 2, 128, 2047, 512, jnp.float32),
    (1, 512, 4, 4, 64, 0, 0, jnp.float32),     # first token
    (2, 512, 8, 4, 64, 511, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("b,s,h,kv,d,pos,window,dtype", CASES)
def test_decode_matches_oracle(b, s, h, kv, d, pos, window, dtype):
    ks = jax.random.split(jax.random.key(pos + s), 3)
    q = jax.random.normal(ks[0], (b, 1, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    out = flash_decode(q, k, v, jnp.int32(pos), window=window, block_k=256,
                       interpret=True)
    exp = ref.decode_attention_naive(q, k, v, pos, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        out.astype(jnp.float32), exp.astype(jnp.float32), atol=tol, rtol=tol
    )


def test_decode_consistent_with_prefill_row():
    """Decode of the token at position p == row p of full flash attention."""
    ks = jax.random.split(jax.random.key(11), 3)
    s, p = 256, 255
    q_full = jax.random.normal(ks[0], (1, s, 4, 64))
    k = jax.random.normal(ks[1], (1, s, 2, 64))
    v = jax.random.normal(ks[2], (1, s, 2, 64))
    full = ref.attention_naive(q_full, k, v, causal=True)
    dec = flash_decode(q_full[:, p : p + 1], k, v, jnp.int32(p), block_k=128,
                       interpret=True)
    np.testing.assert_allclose(dec[:, 0], full[:, p], atol=2e-5, rtol=2e-5)
