"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import costs, env as env_lib, replay
from repro.core.types import Action
from repro.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


@given(
    x=st.floats(1e5, 2e8), eta=st.floats(0, 1), rho=st.floats(1, 500),
    f=st.floats(5e8, 1e10),
)
@settings(**SETTINGS)
def test_latency_nonneg_and_monotone_in_compute(x, eta, rho, f):
    t = float(costs.local_latency(x, eta, rho, f))
    t_faster = float(costs.local_latency(x, eta, rho, 2 * f))
    assert t >= 0
    assert t_faster <= t + 1e-9


@given(
    t_local=st.floats(0, 100), t_edge=st.floats(0, 100),
    e_local=st.floats(0, 100), e_edge=st.floats(0, 100),
)
@settings(**SETTINGS)
def test_totals_bounds(t_local, t_edge, e_local, e_edge):
    t = float(costs.total_latency(t_local, t_edge))
    assert abs(t - max(t_local, t_edge)) <= 1e-5 * max(1.0, t)  # f32 rounding
    e_f = float(costs.total_energy(e_local, e_edge, True))
    e_c = float(costs.total_energy(e_local, e_edge, False))
    assert e_f <= e_c + 1e-5  # max <= sum for nonnegatives


@given(
    seed=st.integers(0, 2**16), m=st.integers(2, 8), k=st.integers(2, 5),
    target=st.integers(0, 3), eta=st.floats(0, 1),
)
@settings(**SETTINGS)
def test_env_step_invariants(seed, m, k, target, eta):
    p = env_lib.default_params(num_eds=m, num_models=k)
    state = env_lib.reset(jax.random.key(seed), p)
    act = Action(
        target=jnp.full((m,), min(target, p.num_ess), jnp.int32),
        eta=jnp.full((m,), eta),
        beta=jnp.ones((m,)),
    )
    nxt, obs, out, done = env_lib.step(state, act, p)
    assert bool(jnp.all(out.latency >= 0)) and bool(jnp.all(out.energy >= 0))
    assert bool(jnp.all(nxt.cache.sum(axis=1) <= p.cache_slots))
    assert obs.shape == (m, env_lib.obs_dim(p))
    assert bool(jnp.all(jnp.isfinite(obs)))


@given(cap=st.integers(2, 16), writes=st.integers(1, 40))
@settings(**SETTINGS)
def test_replay_size_never_exceeds_capacity(cap, writes):
    buf = replay.init(cap, {"x": jnp.zeros(())})
    for i in range(writes):
        buf = replay.add_batch(buf, {"x": jnp.full((1,), float(i))}, 1)
    assert int(buf.size) <= cap
    assert int(buf.size) == min(writes, cap)
    assert 0 <= int(buf.ptr) < cap


@given(
    s=st.sampled_from([32, 64, 96]), h=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 100),
)
@settings(max_examples=10, deadline=None)
def test_ssd_chunked_equals_recurrent(s, h, seed):
    ks = jax.random.split(jax.random.key(seed), 5)
    b, p, n = 1, 16, 8
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bb = jax.random.normal(ks[3], (b, s, n))
    cc = jax.random.normal(ks[4], (b, s, n))
    d = jnp.ones((h,))
    y1, s1 = ref.ssd_chunked_xla(x, dt, a_log, bb, cc, d, chunk=32)
    y2, s2 = ref.ssd_naive(x, dt, a_log, bb, cc, d)
    np.testing.assert_allclose(y1, y2, atol=1e-3, rtol=1e-3)


@given(
    seed=st.integers(0, 2**16),
    n_cells=st.integers(1, 4),
    per_cell=st.integers(1, 4),
    cloud=st.booleans(),
    policy=st.sampled_from(["greedy", "load", "drain"]),
    chunk=st.sampled_from([16, 48]),
    deadline=st.booleans(),
    spill=st.booleans(),
    outage=st.booleans(),
    eta=st.sampled_from([False, "zero", "mixed"]),
    beta=st.sampled_from([False, "download", "refuse", "mixed"]),
)
@settings(max_examples=8, deadline=None)
def test_all_router_paths_agree(seed, n_cells, per_cell, cloud, policy,
                                chunk, deadline, spill, outage, eta, beta):
    """Random fleets/streams/policies — optionally under a mixed-SLO
    deadline column, a random neighbour-cell spill adjacency, a random
    server-outage mask and the eq. 16 action knobs (partial-offload
    eta ratios, download-refusal beta): scan, chunked, speculative and
    mesh-sharded ``route_batch`` agree with each other (sharded
    bitwise, rejection causes included) and with the scalar oracle. The
    same driver runs seed-pinned in ``test_mesh_router.py`` for
    hypothesis-free environments."""
    from fuzz_paths import check_router_paths_agree

    check_router_paths_agree(seed, n_cells, per_cell, cloud, policy, chunk,
                             deadline=deadline, spill=spill, outage=outage,
                             eta=eta, beta=beta)


@given(
    sq=st.sampled_from([64, 128]), win=st.sampled_from([0, 32]),
    seed=st.integers(0, 100),
)
@settings(max_examples=10, deadline=None)
def test_attention_causality(sq, win, seed):
    """Perturbing future keys must not change earlier outputs."""
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (1, sq, 2, 32))
    k = jax.random.normal(ks[1], (1, sq, 2, 32))
    v = jax.random.normal(ks[2], (1, sq, 2, 32))
    out1 = ref.attention_naive(q, k, v, causal=True, window=win)
    k2 = k.at[:, sq // 2 :].add(100.0)
    v2 = v.at[:, sq // 2 :].add(100.0)
    out2 = ref.attention_naive(q, k2, v2, causal=True, window=win)
    np.testing.assert_allclose(
        out1[:, : sq // 2], out2[:, : sq // 2], atol=1e-5, rtol=1e-5
    )
