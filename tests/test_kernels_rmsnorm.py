"""Fused RMSNorm kernel vs oracle across shapes/dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.rmsnorm import rmsnorm


@pytest.mark.parametrize("shape", [(4, 64, 256), (2, 128), (3, 5, 7, 64), (1, 100)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches(shape, dtype):
    key = jax.random.key(sum(shape))
    x = jax.random.normal(key, shape, dtype)
    scale = jnp.linspace(0.5, 1.5, shape[-1]).astype(dtype)
    out = rmsnorm(x, scale, interpret=True)
    exp = ref.rmsnorm_naive(x, scale)
    np.testing.assert_allclose(
        out.astype(jnp.float32), exp.astype(jnp.float32), atol=2e-2, rtol=2e-2
    )


def test_rmsnorm_unit_rms():
    x = jax.random.normal(jax.random.key(0), (64, 128)) * 7.0
    out = rmsnorm(x, jnp.ones((128,)), interpret=True)
    rms = jnp.sqrt(jnp.mean(out**2, axis=-1))
    np.testing.assert_allclose(rms, jnp.ones_like(rms), atol=1e-3)
