"""Paper Fig. 3 — performance vs. number of AIGC model types (K = 3..6).

Also validates the paper's headline claims (§IV.C.1): averaged over the
four model counts, MADDPG-MATO achieves ~6.98% lower latency, ~7.12%
lower energy and ~3.72% higher completion than the baselines.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common

KS = (3, 4, 5, 6)
METRICS = ("latency", "energy", "completion")


def run(m: int = 10, seed: int = 0):
    table = {}
    for k in KS:
        for algo in common.ALL_ALGOS:
            table[(algo, k)] = common.run_cell(algo, k, m, seed)["eval"]
    return table


def headline(table):
    """MATO vs the strongest baseline, averaged over K."""
    out = {}
    for metric in METRICS:
        mato = np.mean([table[("maddpg-mato", k)][metric] for k in KS])
        per_base = {
            a: np.mean([table[(a, k)][metric] for k in KS])
            for a in common.ALL_ALGOS
            if a != "maddpg-mato"
        }
        if metric == "completion":
            best = max(per_base.values())
            out[metric] = (mato - best) / max(best, 1e-9) * 100.0
        else:
            best = min(per_base.values())
            out[metric] = (best - mato) / max(best, 1e-9) * 100.0
        out[f"{metric}_baselines"] = per_base
        out[f"{metric}_mato"] = float(mato)
    return out


def main():
    table = run()
    print("# Fig.3 model sweep")
    print("algo,num_models,latency_s,energy_j,completion")
    for k in KS:
        for algo in common.ALL_ALGOS:
            ev = table[(algo, k)]
            print(
                f"{algo},{k},{ev['latency']:.3f},{ev['energy']:.3f},"
                f"{ev['completion']:.3f}"
            )
    h = headline(table)
    print("\n# headline vs strongest baseline (paper: 6.98% lat, 7.12% en, 3.72% comp)")
    print(f"latency_reduction_pct,{h['latency']:.2f}")
    print(f"energy_reduction_pct,{h['energy']:.2f}")
    print(f"completion_gain_pct,{h['completion']:.2f}")


if __name__ == "__main__":
    main()
