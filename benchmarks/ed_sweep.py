"""Paper Fig. 4 — performance vs. number of EDs (M = 5..20).

Checks the §IV.C.2 claim: with 20 EDs MADDPG-MATO keeps the highest
completion rate (paper: 98%, ≥11.3% above baselines; MADDPG-NoModel ~88%).
"""
from __future__ import annotations

from benchmarks import common

MS = (5, 10, 15, 20)


def run(k: int = 3, seed: int = 0):
    table = {}
    for m in MS:
        for algo in common.ALL_ALGOS:
            table[(algo, m)] = common.run_cell(algo, k, m, seed)["eval"]
    return table


def main():
    table = run()
    print("# Fig.4 ED sweep")
    print("algo,num_eds,latency_s,energy_j,completion")
    for m in MS:
        for algo in common.ALL_ALGOS:
            ev = table[(algo, m)]
            print(
                f"{algo},{m},{ev['latency']:.3f},{ev['energy']:.3f},"
                f"{ev['completion']:.3f}"
            )
    mato20 = table[("maddpg-mato", 20)]["completion"]
    others = [table[(a, 20)]["completion"] for a in common.ALL_ALGOS if a != "maddpg-mato"]
    print("\n# 20-ED completion (paper: MATO 98%, >= +11.3% vs others)")
    print(f"mato_completion,{mato20:.3f}")
    print(f"best_other_completion,{max(others):.3f}")


if __name__ == "__main__":
    main()
