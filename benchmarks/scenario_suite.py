"""Policies x scenarios matrix: every registered workload scenario
routed by every serving policy through the long-horizon simulator.

Where ``policy_serving`` measures decision quality on ONE hand-tuned
bursty stream, this suite sweeps the whole scenario registry
(``repro.workloads``): steady Poisson, Markov-modulated bursts, diurnal
cycle, flash crowd, popularity drift, hotspot cell. Each cell of the
matrix windows the stream through ``workloads.simulate`` (fleet state
carried across windows) and records the paper's headline metrics —
eq. 11 latency, eq. 6/8/10 energy, completion, model-hit rate — plus
the per-window time series (latency / hit / queue-depth percentiles).

The fleet is sized so the model-switching dynamic is OBSERVABLE: K=6
catalogue models (the paper's 3–6 range) against 2 servers x 2 cache
slots per cell and NO cloud column — per-cell cache covers only 4 of 6
models, so popularity shifts force eq. 7 switches instead of
disappearing into an all-resident cloud fallback. The headline
comparison: ``popularity-drift`` shows a measurably lower model-hit
rate than ``steady`` under the same policy — the switching dynamic the
paper is about.

    PYTHONPATH=src python -m benchmarks.scenario_suite

prints the CSV matrix (``name,us_per_call,derived``) and rewrites
``benchmarks/BENCH_scenarios.json`` — the recorded scenario-quality
trajectory alongside BENCH_policy.json and BENCH_router.json.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from repro.core import batch_router as br
from repro.core.catalog import build_catalog
from repro.launch.serve import make_multicell_fleet
from repro.workloads import (compile_scenario, get_scenario, list_scenarios,
                             simulate)
from repro.workloads.simulate import mean_request_energy_j

# K=6 models (the paper's 3-6 model range), small enough to stay edgy
ARCHS = ["smollm_135m", "starcoder2_3b", "mamba2_2p7b", "musicgen_medium",
         "zamba2_7b", "qwen3_32b"]
JSON_PATH = pathlib.Path(__file__).parent / "BENCH_scenarios.json"

# 2 cells x 2 servers x 2 slots, NO cloud: each cell caches 4 of the 6
# models, so residency churn surfaces as eq. 7 switches (hit-rate dips)
CELLS = 2
SERVERS_PER_CELL = 2
CACHE_SLOTS = 2
DRAIN_RATE = 3e4      # tokens/sec — comparable to decode throughput
WINDOW = 256          # simulator window (requests per route_batch call)
SEED = 0

POLICIES = ("greedy", "drain", "load")


def _jsonable(v):
    """Round for compactness; non-finite (an inf mean over an empty
    window) becomes null — bare ``Infinity`` is not valid JSON."""
    v = float(v)
    return round(v, 6) if np.isfinite(v) else None


def _series_payload(series):
    """SimResult -> compact JSON (rounded per-window lists)."""
    out = {}
    for field, val in zip(series._fields, series):
        if val is None:
            continue
        out[field] = [_jsonable(v) for v in np.asarray(val)]
    return out


def main(scenarios=None, policies=POLICIES, emit_json=True, header=True):
    if header:
        print("name,us_per_call,derived")
    catalog = build_catalog(ARCHS)
    fleet = make_multicell_fleet(CELLS, SERVERS_PER_CELL, catalog,
                                 slots=CACHE_SLOTS, drain_rate=DRAIN_RATE,
                                 cloud=False)
    params, state0 = br.fleet_from_servers(fleet, catalog)
    # default to the fault-free core family: the degraded-service
    # scenarios carry SLO/fault schedules that only mean something under
    # benchmarks/degraded_suite.py, which passes them to the simulator
    scenarios = list(scenarios or [
        n for n in list_scenarios()
        if not (get_scenario(n).deadline_mix
                or get_scenario(n).faults.outages
                or get_scenario(n).faults.drain_outages)
    ])

    results = {}
    for name in scenarios:
        spec = get_scenario(name)
        reqs = compile_scenario(spec, seed=SEED, num_models=len(catalog),
                                num_cells=CELLS)
        n = int(reqs.model.shape[0])
        results[name] = {"spec": spec._asdict(), "policies": {}}
        for pol in policies:
            # warmup run: jit compiles per (window shape, policy); the
            # timed pass below then measures routing, not compilation
            _, out, _ = simulate(params, state0, reqs, policy=pol,
                                 window_requests=WINDOW)
            jax.block_until_ready(out.choice)
            t0 = time.perf_counter()
            _, out, series = simulate(params, state0, reqs, policy=pol,
                                      window_requests=WINDOW)
            jax.block_until_ready(out.choice)
            wall = time.perf_counter() - t0
            s = br.stats(out)
            s["mean_energy_j"] = mean_request_energy_j(params, reqs, out)
            s["queue_p90_peak"] = float(series.queue_p90.max())
            s["route_s"] = round(wall, 4)
            results[name]["policies"][pol] = {
                "aggregate": {k: _jsonable(v) for k, v in s.items()},
                "series": _series_payload(series),
            }
            print(
                f"scenario_{name}_{pol}_b{n},"
                f"{wall / n * 1e6:.2f},"
                f"latency={s['mean_latency']:.4f}"
                f";energy_j={s['mean_energy_j']:.4f}"
                f";completion={s['completion_rate']:.3f}"
                f";hit_rate={s['residency_hit_rate']:.3f}"
                f";queue_p90_peak={s['queue_p90_peak']:.0f}"
            )

    if {"steady", "popularity-drift"} <= set(scenarios):
        for pol in policies:
            hs = results["steady"]["policies"][pol]["aggregate"]
            hd = results["popularity-drift"]["policies"][pol]["aggregate"]
            print(f"# drift check [{pol}]: hit "
                  f"steady={hs['residency_hit_rate']:.3f} -> "
                  f"drift={hd['residency_hit_rate']:.3f}")

    if emit_json:
        payload = {
            "shape": {
                "archs": ARCHS, "cells": CELLS,
                "servers_per_cell": SERVERS_PER_CELL,
                "cache_slots": CACHE_SLOTS, "cloud": False,
                "drain_rate": DRAIN_RATE, "window_requests": WINDOW,
                "seed": SEED,
            },
            "scenarios": results,
        }
        JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
        lead = policies[0]
        print(f"wrote {JSON_PATH.name}: "
              + " ".join(
                  f"{k}={v['policies'][lead]['aggregate']['residency_hit_rate']:.3f}"
                  for k, v in results.items()))
    return results


if __name__ == "__main__":
    main()
