"""Multi-cell fleet routing throughput: C cells x N servers x B requests
in ONE jitted ``core.batch_router.route_batch`` call.

Sweeps cell counts C in {1, 2, 4}, per-cell fleet sizes and batch sizes,
with every fleet carrying the block-diagonal cell mask, a fleet-wide
cloud-fallback column and a time-based drain (tokens/sec folded into the
scan carry, queue decay tracking Poisson arrival stamps). Small cells are
verified request-for-request against the scalar ``ModelAwareRouter``
oracle before timing; large cells are timed only.

    PYTHONPATH=src python -m benchmarks.multicell_throughput

CSV convention: ``name,us_per_call,derived`` (us per ROUTED REQUEST).
"""
from __future__ import annotations

import copy
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batch_router as br
from repro.core.catalog import build_catalog
from repro.core.router import ModelAwareRouter, Request
from repro.launch.serve import make_multicell_fleet

CELL_COUNTS = (1, 2, 4)
SERVERS_PER_CELL = (16,)
BATCH_SIZES = (1024, 4096)
DRAIN_RATE = 50.0        # tokens/sec per server
ARRIVAL_RATE = 2000.0    # fleet-wide requests/sec
VERIFY_MAX = 512         # oracle-check cells up to this batch size
EDGE_ARCHS = ["smollm_135m", "starcoder2_3b", "mamba2_2p7b", "musicgen_medium"]


def make_stream(rng, n_requests, num_models, n_cells):
    return br.RequestBatch(
        model=jnp.asarray(rng.integers(0, num_models, n_requests), jnp.int32),
        prompt_bits=jnp.asarray(rng.uniform(1e5, 1e6, n_requests), jnp.float32),
        gen_tokens=jnp.asarray(rng.integers(1, 32, n_requests), jnp.float32),
        cell=jnp.asarray(rng.integers(0, n_cells, n_requests), jnp.int32),
        arrival_s=jnp.asarray(
            np.cumsum(rng.exponential(1.0 / ARRIVAL_RATE, n_requests)),
            jnp.float32,
        ),
    )


def verify_against_oracle(fleet, catalog, reqs):
    """Per-cell scalar oracle must agree on every routing choice."""
    router = ModelAwareRouter(copy.deepcopy(fleet), catalog)
    expected = [
        router.route(Request(int(m), float(b), int(t), cell=int(c),
                             arrival_s=float(a)))[0]
        for m, b, t, c, a in zip(
            np.asarray(reqs.model), np.asarray(reqs.prompt_bits),
            np.asarray(reqs.gen_tokens), np.asarray(reqs.cell),
            np.asarray(reqs.arrival_s),
        )
    ]
    params, state = br.fleet_from_servers(fleet, catalog)
    _, out = br.route_batch(params, state, reqs)
    assert np.array_equal(np.asarray(out.choice), np.array(expected)), (
        "multi-cell batched router diverged from the scalar oracle"
    )


def time_cell(n_cells, servers_per_cell, n_requests, seed=0, repeats=3):
    catalog = build_catalog(EDGE_ARCHS)
    rng = np.random.default_rng(seed)
    fleet = make_multicell_fleet(n_cells, servers_per_cell, catalog,
                                 drain_rate=DRAIN_RATE)
    reqs = make_stream(rng, n_requests, len(catalog), n_cells)
    if n_requests <= VERIFY_MAX:
        verify_against_oracle(fleet, catalog, reqs)

    params, state = br.fleet_from_servers(fleet, catalog)
    _, out = br.route_batch(params, state, reqs)  # compile
    jax.block_until_ready(out.choice)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        _, out = br.route_batch(params, state, reqs)
        jax.block_until_ready(out.choice)
        best = min(best, time.perf_counter() - t0)
    return best


def main(cell_counts=CELL_COUNTS, servers_per_cell=SERVERS_PER_CELL,
         batch_sizes=BATCH_SIZES, header=True):
    if header:  # run.py already printed the combined-stream header
        print("name,us_per_call,derived")
    # oracle anchor: one small verified cell per C before the timed sweep
    for c in cell_counts:
        time_cell(c, 4, 256)
    for c in cell_counts:
        for n in servers_per_cell:
            for b in batch_sizes:
                t = time_cell(c, n, b)
                print(
                    f"router_multicell_c{c}_n{c * n}_b{b},{t / b * 1e6:.2f},"
                    f"req_per_s={b / t:.0f}"
                )


if __name__ == "__main__":
    main()
