"""Shared harness for the paper-reproduction benchmarks.

Training runs are expensive (minutes each on one CPU core), so every
(algorithm, K models, M EDs, seed) cell is cached as JSON under
``benchmarks/results/``. Re-running a benchmark re-uses the cache;
delete the directory for a fresh sweep.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import env as env_lib, evaluate, maddpg

RESULTS = Path(__file__).resolve().parent / "results"

# Full-fidelity settings used for all paper figures (the cached sweep).
# EXPERIMENTS.md §Paper documents an update_every=5 + lr_critic=2e-3 ablation
# that strengthens model-aware behaviour at high model diversity.
TRAIN_STEPS = 8000
BATCH = 512
EVAL_EPISODES = 64

LEARNED = {
    "maddpg-mato": dict(centralized_critic=True, model_aware=True),
    "maddpg-nomodel": dict(centralized_critic=True, model_aware=False),
    "saddpg": dict(centralized_critic=False, model_aware=True),
}
HEURISTIC = ["random", "greedy"]
ALL_ALGOS = list(LEARNED) + HEURISTIC


def make_cfg(**overrides) -> maddpg.AlgoConfig:
    base = dict(
        total_steps=TRAIN_STEPS,
        batch_size=BATCH,
        warmup=1500,
        update_every=10,
        n_envs=4,
    )
    base.update(overrides)
    return maddpg.AlgoConfig(**base)


def cell_path(algo: str, k: int, m: int, seed: int) -> Path:
    return RESULTS / f"{algo}_K{k}_M{m}_seed{seed}.json"


def run_cell(algo: str, k: int, m: int, seed: int = 0, verbose: bool = True) -> dict:
    """Train (if learned) + evaluate one cell; cached."""
    path = cell_path(algo, k, m, seed)
    if path.exists():
        return json.loads(path.read_text())

    p = env_lib.default_params(num_eds=m, num_models=k)
    t0 = time.time()
    if algo in LEARNED:
        cfg = make_cfg(**LEARNED[algo])
        ts, metrics = maddpg.train_jit(jax.random.key(seed), p, cfg)
        reward_curve = np.asarray(metrics["reward"])
        # per-episode averages for the convergence figure
        ep = reward_curve[: (len(reward_curve) // p.episode_len) * p.episode_len]
        ep = ep.reshape(-1, p.episode_len).mean(-1)
        ev = evaluate.evaluate_policy(
            jax.random.key(seed + 1000), "actor", p, cfg=cfg, params=ts.actor,
            episodes=EVAL_EPISODES,
        )
        out = {"eval": ev, "episode_reward": [float(x) for x in ep]}
    else:
        ev = evaluate.evaluate_policy(
            jax.random.key(seed + 1000), algo, p, episodes=EVAL_EPISODES
        )
        out = {"eval": ev, "episode_reward": []}
    out["wall_s"] = time.time() - t0
    out["setting"] = {"algo": algo, "K": k, "M": m, "seed": seed}

    RESULTS.mkdir(exist_ok=True)
    path.write_text(json.dumps(out))
    if verbose:
        print(
            f"[{algo} K={k} M={m} seed={seed}] {out['wall_s']:.0f}s "
            + " ".join(f"{kk}={vv:.3f}" for kk, vv in ev.items()),
            flush=True,
        )
    return out
