"""Faithful-vs-corrected cost-model ablation (DESIGN.md §3).

The paper prints eq. 4 without the (1-eta) split and eq. 14 as a max of
energies. This table quantifies how much those quirks change the measured
system metrics under identical policies — evidence that the corrected
variants used in the main benchmarks do not change the qualitative story.
"""
from __future__ import annotations

import jax

from repro.core import env as env_lib, evaluate


def main():
    print("# faithful (eqs. as printed) vs corrected cost model")
    print("mode,algo,latency_s,energy_j,completion")
    for faithful in (False, True):
        p = env_lib.default_params(num_eds=10, num_models=3, faithful=faithful)
        for algo in ("random", "greedy"):
            m = evaluate.evaluate_policy(jax.random.key(5), algo, p, episodes=32)
            tag = "faithful" if faithful else "corrected"
            print(f"{tag},{algo},{m['latency']:.3f},{m['energy']:.3f},"
                  f"{m['completion']:.3f}")


if __name__ == "__main__":
    main()
