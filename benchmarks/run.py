"""Benchmark entry point: one section per paper table/figure + the
framework's own microbenchmarks + the roofline summary.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --list     # registered sections
    PYTHONPATH=src python -m benchmarks.run --only router_throughput,scenarios
    PYTHONPATH=src python -m benchmarks.run --only router_throughput --smoke

CSV convention per scaffold: ``name,us_per_call,derived``.
Paper-figure sections read the cached training results in
``benchmarks/results/`` (populate with ``python -m benchmarks.populate``).
Every section is registered in ``SECTIONS`` — CI smoke-checks the
registration via ``--list`` so new benchmarks can't silently drop out.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def _timeit(fn, *args, n=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


def bench_env_step():
    """IIoT environment throughput (vectorised, jitted)."""
    from repro.core import baselines, env as env_lib

    p = env_lib.default_params(num_eds=10, num_models=3)
    state = env_lib.reset(jax.random.key(0), p)
    obs = env_lib.observe(state, p)

    @jax.jit
    def step(state, key):
        act = baselines.random_policy(key, env_lib.observe(state, p), p)
        nxt, _, out, _ = env_lib.step(state, act, p)
        return nxt, out.reward.sum()

    us = _timeit(lambda: step(state, jax.random.key(1))[1])
    print(f"env_step_10ed,{us:.1f},agent_steps_per_s={10e6 / us:.0f}")


def bench_maddpg_update():
    from repro.core import env as env_lib, maddpg, replay

    p = env_lib.default_params(num_eds=10, num_models=3)
    cfg = maddpg.AlgoConfig(batch_size=512)
    ts = maddpg.init_state(jax.random.key(0), p, cfg)
    ex = maddpg.make_transition_example(p, cfg)
    buf = replay.init(2048, ex)
    buf = replay.add_batch(
        buf, jax.tree.map(lambda x: jnp.ones((2048,) + x.shape, x.dtype), ex), 2048
    )
    batch = replay.sample(buf, jax.random.key(1), cfg.batch_size)
    upd = jax.jit(lambda t: maddpg.update(t, batch, jax.random.key(2), p, cfg))
    us = _timeit(upd, ts)
    print(f"maddpg_update_b512,{us:.1f},updates_per_s={1e6 / us:.2f}")


def bench_kernels():
    from repro.kernels import ref

    q = jax.random.normal(jax.random.key(0), (4, 1024, 8, 64), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (4, 1024, 2, 64), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (4, 1024, 2, 64), jnp.float32)
    att = jax.jit(lambda a, b, c: ref.attention_xla(a, b, c, causal=True))
    us = _timeit(att, q, k, v)
    flops = 4 * 4 * 8 * 1024 * 1024 * 64 / 2  # causal
    print(f"attention_xla_4x1024x8x64,{us:.1f},gflops_per_s={flops / us / 1e3:.1f}")

    x = jax.random.normal(jax.random.key(3), (2, 2048, 8, 64))
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(4), (2, 2048, 8)))
    a_log = jax.random.normal(jax.random.key(5), (8,)) * 0.5
    b = jax.random.normal(jax.random.key(6), (2, 2048, 64))
    c = jax.random.normal(jax.random.key(7), (2, 2048, 64))
    d = jnp.ones((8,))
    ssd = jax.jit(lambda *a: ref.ssd_chunked_xla(*a, chunk=256)[0])
    us = _timeit(ssd, x, dt, a_log, b, c, d)
    print(f"ssd_xla_2x2048x8x64,{us:.1f},tokens_per_s={2 * 2048 * 1e6 / us:.0f}")

    xr = jax.random.normal(jax.random.key(8), (4096, 2048), jnp.bfloat16)
    sc = jnp.ones((2048,), jnp.bfloat16)
    rms = jax.jit(lambda a, s: ref.rmsnorm_naive(a, s))
    us = _timeit(rms, xr, sc)
    gb = 2 * xr.size * 2 / 1e9
    print(f"rmsnorm_4096x2048,{us:.1f},gb_per_s={gb * 1e6 / us:.1f}")


def bench_router_throughput(smoke=False):
    """Fleet-scale routing: scalar oracle vs scan vs chunked vs the
    speculative parallel commit (incl. the N=64 B=4096 acceptance cell,
    which refreshes benchmarks/BENCH_router.json). With --smoke, a
    tiny-shape pass that exercises every path (no timing, no JSON)."""
    from benchmarks import router_throughput

    if smoke:
        router_throughput.main(header=False, smoke=True)
        return
    # one representative cell per size regime; the full sweep is
    # ``python -m benchmarks.router_throughput``
    router_throughput.main(fleet_sizes=(16, 64), batch_sizes=(1024, 4096),
                           header=False)


def bench_score_kernel():
    """Fused (B, N) eq. 11 score contraction (chunked phase 1)."""
    from benchmarks import score_kernel

    score_kernel.main(shapes=((4096, 64),), header=False)


def bench_score_roofline():
    """Roofline terms (+ TPU timing when on TPU) for the route-score
    kernel at B >= 64k, where the (B, N) panel exceeds VMEM; refreshes
    benchmarks/BENCH_score_roofline.json."""
    from benchmarks import score_roofline

    score_roofline.main(header=False)


def bench_multicell():
    """Multi-cell fleets + time-based drain, one jitted call per batch."""
    from benchmarks import multicell_throughput

    # the acceptance cell (C=4, N=64, B=1024); the full sweep is
    # ``python -m benchmarks.multicell_throughput``
    multicell_throughput.main(cell_counts=(4,), servers_per_cell=(16,),
                              batch_sizes=(1024,), header=False)


def bench_fleet_scale(smoke=False):
    """Mesh-sharded fleet routing (core.mesh_router): req/s vs device
    count at C=64 cells, N=1024 edge + cloud, B=256k requests/window on
    a forced-8-device host; refreshes benchmarks/BENCH_fleet.json. With
    --smoke, tiny shapes + a bitwise parity assert vs the plain scan
    (no timing, no JSON)."""
    from benchmarks import fleet_scale

    fleet_scale.main(header=False, smoke=smoke)


def bench_policy_serving(smoke=False):
    """Policy QUALITY (not req/s): greedy vs drain-aware vs a trained
    MADDPG-MATO actor checkpoint — target-only AND the full eq. 16
    action (eta/beta head columns) — on the same bursty multi-cell
    stream; refreshes benchmarks/BENCH_policy.json. Trains a
    short-budget checkpoint on first run (cached under
    benchmarks/results/). With --smoke, a toy untrained actor asserts
    the eta/beta columns are honoured end to end (bitwise no-op for
    all-ones knobs, refusal zeroes download_rate); no training, no
    timing, no BENCH JSON."""
    from benchmarks import policy_serving

    if smoke:
        policy_serving.smoke()
        return
    policy_serving.main(header=False)


def bench_scenarios():
    """Policies x scenarios matrix through the long-horizon workload
    simulator (repro.workloads); refreshes benchmarks/
    BENCH_scenarios.json."""
    from benchmarks import scenario_suite

    scenario_suite.main(header=False)


def bench_degraded(smoke=False):
    """Degraded-service scenarios (slo-mix / flash-crowd-outage /
    drain-outage) with per-cause rejection rates + the SLO queue-bound
    acceptance check; refreshes benchmarks/BENCH_degraded.json. With
    --smoke, one tiny episode asserting admission AND outage rejections
    end to end (no timing, no JSON)."""
    from benchmarks import degraded_suite

    degraded_suite.main(header=False, smoke=smoke)


def bench_train_step():
    from repro.configs import get_arch, reduced
    from repro.data import pipeline
    from repro.models import lm
    from repro.models.train import make_train_step

    cfg = reduced(get_arch("smollm_135m"))
    params = lm.init_params(jax.random.key(0), cfg)
    dc = pipeline.DataConfig(seq_len=128, global_batch=4, vocab=cfg.vocab)
    batch = pipeline.synthetic_batch(cfg, dc, 0)
    opt_init, step = make_train_step(cfg)
    opt = opt_init(params)
    jit_step = jax.jit(step)
    us = _timeit(lambda: jit_step(params, opt, batch)[2]["loss"], n=3, warmup=1)
    print(f"lm_train_step_reduced,{us:.1f},tokens_per_s={4 * 128 * 1e6 / us:.0f}")


def paper_tables():
    from benchmarks import convergence, ed_sweep, model_sweep

    print("\n=== paper Fig.2 (convergence) ===")
    try:
        convergence.main()
    except Exception as e:  # cache missing
        print(f"(skipped: {e})")
    print("\n=== paper Fig.3 (model sweep) ===")
    try:
        model_sweep.main()
    except Exception as e:
        print(f"(skipped: {e})")
    print("\n=== paper Fig.4 (ED sweep) ===")
    try:
        ed_sweep.main()
    except Exception as e:
        print(f"(skipped: {e})")


def roofline_table():
    from benchmarks import roofline

    print("\n=== roofline (from dry-run artifacts) ===")
    try:
        roofline.main()
        print()
        roofline.main_multipod()
    except Exception as e:
        print(f"(skipped: {e})")


def faithful_table():
    from benchmarks import faithful_ablation

    print("\n=== faithful-vs-corrected cost model (DESIGN.md §3) ===")
    try:
        faithful_ablation.main()
    except Exception as e:
        print(f"(skipped: {e})")


#: Registered sections, run order. CI pins this registry via ``--list``.
SECTIONS = [
    ("env_step", bench_env_step),
    ("maddpg_update", bench_maddpg_update),
    ("kernels", bench_kernels),
    ("score_kernel", bench_score_kernel),
    ("score_roofline", bench_score_roofline),
    ("router_throughput", bench_router_throughput),
    ("multicell", bench_multicell),
    ("fleet_scale", bench_fleet_scale),
    ("policy_serving", bench_policy_serving),
    ("scenarios", bench_scenarios),
    ("degraded_suite", bench_degraded),
    ("train_step", bench_train_step),
    ("paper_tables", paper_tables),
    ("faithful", faithful_table),
    ("roofline", roofline_table),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--list", action="store_true",
                    help="print registered sections and exit (CI smoke)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of sections to run")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-shape pass for sections that support it "
                         "(exercised, not timed; no BENCH files rewritten)")
    args = ap.parse_args(argv)
    if args.list:
        for name, fn in SECTIONS:
            doc = (fn.__doc__ or "").strip().splitlines() or [""]
            print(f"{name}: {doc[0]}")
        return
    selected = dict(SECTIONS)
    if args.only is not None:
        missing = [n for n in args.only.split(",") if n not in selected]
        if missing:
            raise SystemExit(
                f"unknown sections {missing}; see --list"
            )
        keep = set(args.only.split(","))
        sections = [(n, f) for n, f in SECTIONS if n in keep]
    else:
        sections = SECTIONS
    print("name,us_per_call,derived")
    for _, fn in sections:
        if args.smoke and "smoke" in fn.__code__.co_varnames:
            fn(smoke=True)
        else:
            fn()


if __name__ == "__main__":
    main()
