"""Policy quality under bursty multi-cell serving: greedy vs drain-aware
vs a TRAINED MADDPG-MATO actor, end to end through ``route_batch``.

Unlike the throughput benchmarks (req/s of the same decisions), this one
measures decision QUALITY: the same bursty request stream is routed over
the same multi-cell fleet by each policy, and we record the paper's
headline metrics — predicted eq. 11 latency, per-request energy (the
eq. 6/8/10 serving analogue), completion rate — plus the model-hit rate
and the cloud-fallback rate.

The actor is measured twice: ``actor`` (target head only — the
pre-eq.-16 serving contract, kept for trajectory comparability) and
``actor_full`` (all three eq. 16 heads: ``actor_action_columns``
evaluates the trained eta/beta heads per request and the stream routes
with partial-offload pricing, download refusal and the ObsDefaults
device share). ``actor_full`` latency is the eq. 13 end-to-end max of
the device's retained share and the eta-scaled edge share — a different
physical quantity than full-offload latency, so its gap to greedy is
recorded as its own field, not blended into the target-only trajectory.

``--smoke`` (also via ``benchmarks.run --only policy_serving --smoke``)
skips training/timing entirely: a toy actor asserts the eta/beta columns
are honoured end to end (all-ones knobs bitwise no-op, refusal zeroes
the download rate) — the CI fast-tier hook.

The trained actor is the real thing: if no checkpoint exists under
``benchmarks/results/actor_ckpt``, a short-budget MADDPG-MATO run
(``core.maddpg.train_jit`` on the paper env with the REAL catalogue
model sizes) trains one, saves it through
``core.policies.save_actor_checkpoint`` and the benchmark restores it
exactly the way ``launch.serve --policy actor:<dir>`` does. Delete the
directory for a fresh training run; with the checkpoint cached the
whole benchmark is routing-only.

    PYTHONPATH=src python -m benchmarks.policy_serving

prints the CSV sweep (``name,us_per_call,derived``) and rewrites
``benchmarks/BENCH_policy.json`` — the recorded policy-quality
trajectory alongside ``BENCH_router.json``'s throughput trajectory.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batch_router as br
from repro.core import maddpg, policies
from repro.core.catalog import build_catalog, env_params_from_catalog
from repro.core.router import Request
from repro.launch.serve import make_multicell_fleet
from repro.workloads import generators
from repro.workloads.simulate import mean_request_energy_j

EDGE_ARCHS = ["smollm_135m", "starcoder2_3b", "mamba2_2p7b", "musicgen_medium"]
RESULTS = pathlib.Path(__file__).parent / "results"
CKPT_DIR = RESULTS / "actor_ckpt"
JSON_PATH = pathlib.Path(__file__).parent / "BENCH_policy.json"

# serving shape: C cells x N edge servers + cloud, bursty arrivals
CELLS = 2
SERVERS_PER_CELL = 3
REQUESTS = 1024
BURST = 64            # requests per burst (arrive nearly simultaneously)
BURST_GAP_S = 0.5     # quiet gap between bursts (queues drain here)
DRAIN_RATE = 3e4      # tokens/sec — comparable to the servers' decode
                      # throughput, so drain-aware pricing actually bites
ACTOR_CHUNK = 1024    # chunked-path chunk for the batched actor: one
                      # chunk = one MLP gemm over the whole stream
ACTOR_UNROLL = 4      # scan unroll for the hooked (table-lookup) chunk body

# short-budget training run that produces the served checkpoint
TRAIN = dict(total_steps=600, batch_size=128, warmup=200, update_every=5,
             n_envs=4, explore_decay_steps=400)
TRAIN_EDS = 6


def ensure_checkpoint(verbose=True):
    """Restore-or-train the served actor; returns (ckpt_dir, meta dict)."""
    meta_path = CKPT_DIR / "train_meta.json"
    try:
        policies.load_actor_checkpoint(CKPT_DIR)
        meta = json.loads(meta_path.read_text()) if meta_path.exists() else {}
        return CKPT_DIR, meta
    except (FileNotFoundError, ValueError):
        pass
    catalog = build_catalog(EDGE_ARCHS)
    p = env_params_from_catalog(catalog, num_eds=TRAIN_EDS,
                                num_ess=SERVERS_PER_CELL)
    cfg = maddpg.AlgoConfig(**TRAIN)
    t0 = time.time()
    ts, metrics = maddpg.train_jit(jax.random.key(0), p, cfg)
    jax.block_until_ready(metrics["reward"])
    wall = time.time() - t0
    r0 = float(np.asarray(metrics["reward"])[:50].mean())
    r1 = float(np.asarray(metrics["reward"])[-50:].mean())
    policies.save_actor_checkpoint(CKPT_DIR, ts.actor, p, cfg)
    meta = {
        "train_steps": TRAIN["total_steps"], "train_wall_s": round(wall, 1),
        "num_eds": TRAIN_EDS, "num_ess": SERVERS_PER_CELL,
        "num_models": p.num_models,
        "reward_first50": round(r0, 2), "reward_last50": round(r1, 2),
    }
    meta_path.write_text(json.dumps(meta))
    if verbose:
        print(f"trained actor checkpoint in {wall:.0f}s "
              f"(reward {r0:.1f} -> {r1:.1f}); cached at {CKPT_DIR}")
    return CKPT_DIR, meta


def bursty_stream(rng, n, n_cells, num_models):
    """Bursts of ``BURST`` near-simultaneous requests every
    ``BURST_GAP_S`` seconds, random cells/models — the arrival pattern
    where queue-drain awareness matters. Built from the
    ``workloads.generators`` primitives, consuming ``rng`` in the
    canonical order the original hand-rolled fixture did, so the
    recorded BENCH_policy.json metrics are unchanged."""
    arrivals = generators.burst_train_arrivals(rng, n, BURST, BURST_GAP_S)
    fields = generators.stream_fields(rng, n, num_models, num_cells=n_cells)
    return generators.to_request_batch(fields, arrivals)


def time_policies(specs, params, state, repeats=9):
    """Interleaved best-of wall-clock per policy: each timing round runs
    every policy once before any policy runs again, so process-wide slow
    phases (GC pauses, frequency drift) tax all competitors equally
    instead of whichever happened to be measured first. Each spec
    carries its own request batch (``actor_full`` routes the eta/beta
    columns, everything else the plain stream). Returns
    {name: best seconds}."""
    runners = {}
    for name, policy, reqs, kw in specs:
        def run(policy=policy, reqs=reqs, kw=kw):
            _, out = br.route_batch(params, state, reqs, policy=policy,
                                    **kw)
            jax.block_until_ready(out.choice)
        run()  # compile + warm
        runners[name] = run
    best = {name: float("inf") for name in runners}
    for _ in range(repeats):
        for name, run in runners.items():
            t0 = time.perf_counter()
            run()
            best[name] = min(best[name], time.perf_counter() - t0)
    return best


def route_with(policy, fleet, catalog, params, state, reqs, route_s,
               **route_kw):
    """Route the stream under one policy; returns (stats dict, outcome).
    ``route_s`` is the policy's wall-clock from ``time_policies`` (this
    call routes once more, for the quality metrics only)."""
    _, out = br.route_batch(params, state, reqs, policy=policy,
                            **route_kw)
    jax.block_until_ready(out.choice)
    best = route_s
    # the cloud column is appended last by make_multicell_fleet
    s = br.stats(out, cloud_index=np.asarray(params.flops_per_s).shape[0] - 1)
    # fair-fight latency: reprice the stream under the drain-corrected
    # cost model (raw eq. 11 is greedy's own objective and overstates
    # the wait behind fast-draining queues). The eq. 16 knob columns
    # ride into the scalar replay so the oracle prices the same action
    # the batch committed; refusal can reject (a refused miss commits
    # nothing), so the replay keeps completed requests only — the same
    # denominator mean_latency uses.
    eta_c = None if reqs.eta is None else np.asarray(reqs.eta)
    beta_c = None if reqs.beta is None else np.asarray(reqs.beta)
    loc_c = (None if reqs.local_flops_per_s is None
             else np.asarray(reqs.local_flops_per_s))
    requests = [
        Request(int(m), float(b), int(t), cell=int(c), arrival_s=float(a),
                eta=None if eta_c is None else float(eta_c[i]),
                beta=None if beta_c is None else bool(beta_c[i]),
                local_flops_per_s=None if loc_c is None else float(loc_c[i]))
        for i, (m, b, t, c, a) in enumerate(zip(
            np.asarray(reqs.model), np.asarray(reqs.prompt_bits),
            np.asarray(reqs.gen_tokens), np.asarray(reqs.cell),
            np.asarray(reqs.arrival_s)))
    ]
    choice = np.asarray(out.choice)
    done = choice >= 0
    s["mean_latency_corrected"] = float(np.mean(
        policies.drain_corrected_latencies(
            fleet, catalog, [r for r, ok in zip(requests, done) if ok],
            choice[done])
    ))
    s["mean_energy_j"] = mean_request_energy_j(params, reqs, out)
    s["route_s"] = round(best, 4)
    s["req_per_s"] = round(reqs.model.shape[0] / best)
    return s, out


def main(emit_json=True, header=True, verbose=True):
    if header:
        print("name,us_per_call,derived")
    ckpt_dir, train_meta = ensure_checkpoint(verbose=verbose)
    catalog = build_catalog(EDGE_ARCHS)
    fleet = make_multicell_fleet(CELLS, SERVERS_PER_CELL, catalog,
                                 drain_rate=DRAIN_RATE)
    params, state = br.fleet_from_servers(fleet, catalog)
    rng = np.random.default_rng(7)
    reqs = bursty_stream(rng, REQUESTS, CELLS, len(catalog))

    actor_params, spec, extra = policies.load_actor_checkpoint(ckpt_dir)
    model_aware = extra.get("model_aware", True)
    actor_policy = policies.make_actor_policy(actor_params, spec, params,
                                              model_aware=model_aware)
    # the full eq. 16 action: the trained eta/beta heads become request
    # columns (evaluated once against the window-entry residency, the
    # policies.actor_action_columns contract) and the device keeps the
    # 1-eta share at the ObsDefaults capacity — the same f_ed the actor
    # observed while choosing eta
    eta, beta = policies.actor_action_columns(
        actor_params, spec, params, state, reqs, model_aware=model_aware)
    dflt = policies.default_obs_defaults(spec)
    full_reqs = reqs._replace(
        eta=eta, beta=beta,
        local_flops_per_s=jnp.full((REQUESTS,), float(dflt.f_ed),
                                   jnp.float32))
    results = {}
    # the actor routes through the chunked path: its chunk-level hook
    # batches the MLP over ACTOR_CHUNK requests per compat-variant table
    # (see core.policies.make_actor_policy) instead of one matvec per
    # request inside the scan. Decisions are identical either way.
    specs = [("greedy", "greedy", reqs, {}),
             ("drain", "drain", reqs, {}),
             ("actor", actor_policy, reqs,
              {"chunk": ACTOR_CHUNK, "unroll": ACTOR_UNROLL}),
             ("actor_full", actor_policy, full_reqs,
              {"chunk": ACTOR_CHUNK, "unroll": ACTOR_UNROLL}),
             ("actor_unbatched", actor_policy, reqs, {})]
    timings = time_policies(specs, params, state)
    for name, policy, rq, kw in specs[:4]:
        s, _ = route_with(policy, fleet, catalog, params, state, rq,
                          timings[name], **kw)
        results[name] = s
        print(
            f"policy_{name}_c{CELLS}_n{SERVERS_PER_CELL}_b{REQUESTS},"
            f"{s['route_s'] / REQUESTS * 1e6:.2f},"
            f"latency={s['mean_latency']:.4f}"
            f";corrected={s['mean_latency_corrected']:.4f}"
            f";energy_j={s['mean_energy_j']:.4f}"
            f";completion={s['completion_rate']:.3f}"
            f";hit_rate={s['residency_hit_rate']:.3f}"
            f";cloud={s['cloud_fallback_rate']:.3f}"
        )
    results["actor"]["chunk"] = ACTOR_CHUNK
    results["actor"]["req_per_s_unbatched"] = round(
        REQUESTS / timings["actor_unbatched"])
    results["actor"]["batched_speedup"] = round(
        results["actor"]["req_per_s"]
        / results["actor"]["req_per_s_unbatched"], 2)
    results["actor"]["gap_to_greedy"] = round(
        results["greedy"]["req_per_s"] / results["actor"]["req_per_s"], 2)
    # the honest quality gap: corrected latency ratio vs greedy, stated
    # per variant. actor_full prices a DIFFERENT physical quantity (the
    # eq. 13 max of device share and eta-scaled edge share, plus beta
    # refusals shifting requests onto resident servers), so its ratio is
    # reported under its own key — a short-budget checkpoint typically
    # trails greedy here and the number says so rather than hiding it.
    for key in ("actor", "actor_full"):
        results[key]["latency_gap_to_greedy"] = round(
            results[key]["mean_latency_corrected"]
            / results["greedy"]["mean_latency_corrected"], 3)
    results["actor_full"]["mean_eta"] = round(float(np.mean(
        np.asarray(eta))), 4)
    results["actor_full"]["beta_download_share"] = round(float(np.mean(
        np.asarray(beta))), 4)

    if emit_json:
        payload = {
            "shape": {
                "cells": CELLS, "servers_per_cell": SERVERS_PER_CELL,
                "cloud": True, "requests": REQUESTS, "burst": BURST,
                "burst_gap_s": BURST_GAP_S, "drain_rate": DRAIN_RATE,
            },
            "checkpoint": {"dir": str(CKPT_DIR.relative_to(JSON_PATH.parent)),
                           **train_meta},
            "policies": results,
        }
        JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
        print(f"wrote {JSON_PATH.name}: latency "
              + " ".join(f"{k}={v['mean_latency']:.3f}"
                         for k, v in results.items()))
    return results


def smoke():
    """CI assertion pass (seconds, CPU): a TOY actor — fresh
    ``networks.stacked_init``, no training, no checkpoint — through the
    full eq. 16 serving path on tiny shapes. Asserts the router honours
    the ``actor_action_columns`` contract:

    * the head columns have the executed squashings (eta strictly inside
      (0, 1) from the sigmoid, beta boolean);
    * all-ones eta/beta columns are a BITWISE no-op vs the knob-free
      route (the compile-out contract);
    * a non-trivial eta column changes the priced latencies;
    * blanket beta refusal zeroes ``download_rate`` (every committed
      refusal is a residency hit);
    * the full action (actor's own columns + ObsDefaults device share)
      still completes requests.

    No timing, no BENCH JSON rewrite."""
    from repro.core import networks

    catalog = build_catalog(EDGE_ARCHS[:2])
    fleet = make_multicell_fleet(1, SERVERS_PER_CELL, catalog)
    params, state = br.fleet_from_servers(fleet, catalog)
    p = env_params_from_catalog(catalog, num_eds=2,
                                num_ess=SERVERS_PER_CELL)
    spec = policies.spec_from_env(p)
    sizes = [policies.obs_dim(spec), 16, 16, spec.num_ess + 1 + 2]
    actor = networks.stacked_init(jax.random.key(1), 2, sizes)
    policy = policies.make_actor_policy(actor, spec, params)
    reqs = bursty_stream(np.random.default_rng(3), 64, 1, len(catalog))
    n = int(reqs.model.shape[0])

    eta, beta = policies.actor_action_columns(actor, spec, params, state,
                                              reqs)
    e = np.asarray(eta)
    assert e.shape == (n,) and ((0.0 < e) & (e < 1.0)).all(), \
        "eta head must be a sigmoid ratio per request"
    assert np.asarray(beta).shape == (n,) and beta.dtype == bool

    _, base = br.route_batch(params, state, reqs, policy=policy)
    _, ones = br.route_batch(
        params, state,
        reqs._replace(eta=jnp.ones(n), beta=jnp.ones(n, bool)),
        policy=policy)
    np.testing.assert_array_equal(np.asarray(base.choice),
                                  np.asarray(ones.choice))
    np.testing.assert_array_equal(np.asarray(base.latency),
                                  np.asarray(ones.latency))

    _, half = br.route_batch(params, state,
                             reqs._replace(eta=jnp.full(n, 0.5)),
                             policy=policy)
    assert not np.array_equal(np.asarray(half.latency),
                              np.asarray(base.latency)), \
        "eta column must reshape the priced latencies"

    _, refuse = br.route_batch(params, state,
                               reqs._replace(beta=jnp.zeros(n, bool)),
                               policy=policy)
    sr = br.stats(refuse)
    assert sr["download_rate"] == 0.0, "refusal must never download"
    assert sr["residency_hit_rate"] == 1.0

    dflt = policies.default_obs_defaults(spec)
    _, out = br.route_batch(
        params, state,
        reqs._replace(eta=eta, beta=beta,
                      local_flops_per_s=jnp.full((n,), float(dflt.f_ed),
                                                 jnp.float32)),
        policy=policy)
    s = br.stats(out)
    assert s["completion_rate"] > 0.0
    print("policy_serving_smoke,0.00,"
          f"eta_beta=honoured;completion={s['completion_rate']:.3f}"
          f";download_rate={s['download_rate']:.3f}")


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv[1:]:
        smoke()
    else:
        main()
