"""Degraded-service suite: the overload-economy scenarios end to end.

Runs the degraded family of the scenario registry (``slo-mix``,
``flash-crowd-outage``, ``drain-outage`` — ``docs/robustness.md``)
through the long-horizon simulator on the SAME fleet template as
``scenario_suite`` (6 catalogue models, 2 cells x 2 servers x 2 slots,
no cloud, continuous drain), recording the honest cost of degradation:
per-cause rejection rates (infeasible / admission / outage) next to the
completion drop, plus the per-window queue series the admission control
is supposed to bound.

The headline acceptance check: under ``flash-crowd-outage`` (a 20x
arrival spike while cell 0's servers are down) the SLO admission
control must keep the peak edge queue p90 within ``QUEUE_BOUND_MULT``
(5x) of the steady-state queue p90 — the same stream with the deadline
column stripped is run as the no-SLO control to show the blow-up the
SLO prevents.

    PYTHONPATH=src python -m benchmarks.degraded_suite

prints the CSV matrix (``name,us_per_call,derived``) and rewrites
``benchmarks/BENCH_degraded.json``.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from benchmarks.scenario_suite import (ARCHS, CACHE_SLOTS, CELLS, DRAIN_RATE,
                                       SEED, SERVERS_PER_CELL, WINDOW,
                                       _jsonable, _series_payload)
from repro.core import batch_router as br
from repro.core.catalog import build_catalog
from repro.launch.serve import make_multicell_fleet
from repro.workloads import compile_scenario, get_scenario, simulate
from repro.workloads.simulate import mean_request_energy_j

JSON_PATH = pathlib.Path(__file__).parent / "BENCH_degraded.json"
SCENARIOS = ("slo-mix", "flash-crowd-outage", "drain-outage")
POLICIES = ("greedy", "drain")
#: acceptance bound: flash-crowd-outage peak queue p90 <= MULT x steady q90
QUEUE_BOUND_MULT = 5.0


def _fleet():
    catalog = build_catalog(ARCHS)
    fleet = make_multicell_fleet(CELLS, SERVERS_PER_CELL, catalog,
                                 slots=CACHE_SLOTS, drain_rate=DRAIN_RATE,
                                 cloud=False)
    return br.fleet_from_servers(fleet, catalog)


def _episode(params, state0, spec, pol):
    """One (scenario, policy) cell: compile the stream, simulate with the
    spec's fault schedule, return (reqs, outcome, series, wall_s)."""
    reqs = compile_scenario(spec, seed=SEED, num_models=len(ARCHS),
                            num_cells=CELLS)
    # warmup pass so the timed one measures routing, not compilation
    _, out, _ = simulate(params, state0, reqs, policy=pol,
                         window_requests=WINDOW, faults=spec.faults)
    jax.block_until_ready(out.choice)
    t0 = time.perf_counter()
    _, out, series = simulate(params, state0, reqs, policy=pol,
                              window_requests=WINDOW, faults=spec.faults)
    jax.block_until_ready(out.choice)
    return reqs, out, series, time.perf_counter() - t0


def smoke_check():
    """Tiny end-to-end pass (no timing, no JSON): the flash-crowd-outage
    episode must produce BOTH admission and outage rejections with the
    four per-cause rates summing to 1 — the whole rejection channel
    exercised through scenario -> FaultSpec -> simulate -> stats."""
    params, state0 = _fleet()
    spec = get_scenario("flash-crowd-outage", num_requests=768)
    reqs = compile_scenario(spec, seed=SEED, num_models=len(ARCHS),
                            num_cells=CELLS)
    _, out, series = simulate(params, state0, reqs, policy="greedy",
                              window_requests=WINDOW, faults=spec.faults)
    cause = np.asarray(out.cause)
    assert (cause == br.CAUSE_ADMISSION).any(), "no admission rejections"
    assert (cause == br.CAUSE_OUTAGE).any(), "no outage rejections"
    total = (series.completion_rate + series.infeasible_rate
             + series.admission_rate + series.outage_rate)
    assert np.allclose(total, 1.0), "per-cause rates must sum to 1"
    n = cause.shape[0]
    print(f"degraded_smoke_b{n},0.00,"
          f"admission={int((cause == br.CAUSE_ADMISSION).sum())}"
          f";outage={int((cause == br.CAUSE_OUTAGE).sum())}"
          f";completed={int((cause == br.CAUSE_COMPLETED).sum())}")


def main(scenarios=SCENARIOS, policies=POLICIES, emit_json=True,
         header=True, smoke=False):
    if smoke:
        smoke_check()
        return None
    if header:
        print("name,us_per_call,derived")
    params, state0 = _fleet()

    results = {}
    for name in scenarios:
        spec = get_scenario(name)
        results[name] = {"spec": spec._asdict(), "policies": {}}
        for pol in policies:
            reqs, out, series, wall = _episode(params, state0, spec, pol)
            n = int(reqs.model.shape[0])
            s = br.stats(out)
            s["mean_energy_j"] = mean_request_energy_j(params, reqs, out)
            s["queue_p90_peak"] = float(series.queue_p90.max())
            s["route_s"] = round(wall, 4)
            results[name]["policies"][pol] = {
                "aggregate": {k: _jsonable(v) for k, v in s.items()},
                "series": _series_payload(series),
            }
            print(
                f"degraded_{name}_{pol}_b{n},"
                f"{wall / n * 1e6:.2f},"
                f"completion={s['completion_rate']:.3f}"
                f";admission={s.get('admission_rate', 0.0):.3f}"
                f";outage={s.get('outage_rate', 0.0):.3f}"
                f";infeasible={s.get('infeasible_rate', 0.0):.3f}"
                f";queue_p90_peak={s['queue_p90_peak']:.0f}"
            )

    # --- the acceptance check: SLO admission as the queue's relief valve
    acceptance = None
    if "flash-crowd-outage" in scenarios:
        pol = policies[0]
        steady = get_scenario("steady")
        _, _, st_series, _ = _episode(params, state0, steady, pol)
        steady_q90 = float(st_series.queue_p90[-1])
        bound = QUEUE_BOUND_MULT * steady_q90
        slo_peak = float(results["flash-crowd-outage"]["policies"][pol]
                         ["aggregate"]["queue_p90_peak"])
        # control: the same spike + outage with the deadline column
        # stripped — what the queue does when nothing says no
        control = get_scenario("flash-crowd-outage")._replace(
            deadline_mix=())
        _, _, ctl_series, _ = _episode(params, state0, control, pol)
        control_peak = float(ctl_series.queue_p90.max())
        acceptance = {
            "policy": pol,
            "steady_queue_p90": _jsonable(steady_q90),
            "bound_mult": QUEUE_BOUND_MULT,
            "bound": _jsonable(bound),
            "slo_queue_p90_peak": _jsonable(slo_peak),
            "control_queue_p90_peak": _jsonable(control_peak),
            "bounded": bool(slo_peak <= bound),
        }
        print(f"# queue bound [{pol}]: steady_q90={steady_q90:.0f} "
              f"bound={bound:.0f} slo_peak={slo_peak:.0f} "
              f"control_peak={control_peak:.0f} "
              f"{'OK' if slo_peak <= bound else 'VIOLATED'}")

    if emit_json:
        payload = {
            "shape": {
                "archs": ARCHS, "cells": CELLS,
                "servers_per_cell": SERVERS_PER_CELL,
                "cache_slots": CACHE_SLOTS, "cloud": False,
                "drain_rate": DRAIN_RATE, "window_requests": WINDOW,
                "seed": SEED,
            },
            "scenarios": results,
            "acceptance": acceptance,
        }
        JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
        print(f"wrote {JSON_PATH.name}: "
              + " ".join(
                  f"{k}={v['policies'][policies[0]]['aggregate']['completion_rate']:.3f}"
                  for k, v in results.items()))
    return results


if __name__ == "__main__":
    main()
