"""Router throughput: scalar loop vs jitted scan vs chunked two-phase.

Measures requests/sec for the scalar ``ModelAwareRouter`` (one Python
call per request), ``core.batch_router.route_batch`` with the
single-scan path (the PR 2 baseline), and the chunked two-phase commit
(``chunk=256``: one fused scoring call per chunk + the slimmed
correction scan) across fleet sizes N in {4, 16, 64} and batch sizes B
in {64, 1024, 4096}, verifying on every cell that all paths agree on
all routing choices.

    PYTHONPATH=src python -m benchmarks.router_throughput

prints the CSV sweep (``name,us_per_call,derived``, us per ROUTED
REQUEST) and rewrites ``benchmarks/BENCH_router.json`` — the recorded
perf trajectory: req/s for the scalar / scan / chunked paths at the
acceptance shape N=64, B=4096 plus the chunked speedup over the scan
path (the PR 3 target is >= 2x).
"""
from __future__ import annotations

import copy
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batch_router as br
from repro.core.catalog import build_catalog
from repro.core.router import EdgeServer, ModelAwareRouter, Request

FLEET_SIZES = (4, 16, 64)
BATCH_SIZES = (64, 1024, 4096)
CHUNK = 256           # two-phase commit chunk at fleet scale
EDGE_ARCHS = ["smollm_135m", "starcoder2_3b", "mamba2_2p7b", "musicgen_medium"]
JSON_PATH = pathlib.Path(__file__).parent / "BENCH_router.json"
ACCEPTANCE = (64, 4096)  # (N, B) cell recorded in BENCH_router.json


def make_fleet(rng, n_servers, catalog, cache_slots=2):
    return [
        EdgeServer(
            name=f"es{i}",
            flops_per_s=float(rng.uniform(5e13, 2e14)),
            cache_slots=cache_slots,
            uplink_bps=1e8,
            backhaul_bps=1e9,
            resident=[(2 * i + j) % len(catalog) for j in range(cache_slots)],
        )
        for i in range(n_servers)
    ]


def make_stream(rng, n_requests, num_models):
    return (
        rng.integers(0, num_models, n_requests),
        rng.uniform(1e5, 1e6, n_requests),
        rng.integers(1, 32, n_requests),
    )


def time_scalar(servers, catalog, models, bits, toks):
    router = ModelAwareRouter(copy.deepcopy(servers), catalog)
    t0 = time.perf_counter()
    choices = [
        router.route(Request(int(m), float(b), int(t)))[0]
        for m, b, t in zip(models, bits, toks)
    ]
    return time.perf_counter() - t0, np.array(choices)


def time_batched(servers, catalog, models, bits, toks, repeats=7, **route_kw):
    params, state = br.fleet_from_servers(servers, catalog)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
    )
    _, out = br.route_batch(params, state, reqs, **route_kw)  # compile
    jax.block_until_ready(out.choice)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        _, out = br.route_batch(params, state, reqs, **route_kw)
        jax.block_until_ready(out.choice)
        best = min(best, time.perf_counter() - t0)
    return best, np.asarray(out.choice)


def run_cell(n_servers, n_requests, seed=0, chunk=CHUNK):
    catalog = build_catalog(EDGE_ARCHS)
    rng = np.random.default_rng(seed)
    servers = make_fleet(rng, n_servers, catalog)
    models, bits, toks = make_stream(rng, n_requests, len(catalog))
    t_scalar, c_scalar = time_scalar(servers, catalog, models, bits, toks)
    t_scan, c_scan = time_batched(servers, catalog, models, bits, toks)
    t_chunked, c_chunked = time_batched(
        servers, catalog, models, bits, toks, chunk=chunk
    )
    assert np.array_equal(c_scalar, c_scan), (
        f"scan router diverged from scalar oracle at N={n_servers} "
        f"B={n_requests}"
    )
    assert np.array_equal(c_scalar, c_chunked), (
        f"chunked router diverged from scalar oracle at N={n_servers} "
        f"B={n_requests}"
    )
    return t_scalar, t_scan, t_chunked


def write_json(cells):
    """Record the perf trajectory (req/s per path) for the acceptance
    cell; cells: {(n, b): (t_scalar, t_scan, t_chunked)}."""
    n, b = ACCEPTANCE
    t_scalar, t_scan, t_chunked = cells[(n, b)]
    payload = {
        "shape": {"servers": n, "requests": b, "chunk": CHUNK},
        "req_per_s": {
            "scalar": round(b / t_scalar),
            "scan": round(b / t_scan),
            "chunked": round(b / t_chunked),
        },
        "chunked_speedup_over_scan": round(t_scan / t_chunked, 2),
        "verified": "all paths agree with the scalar oracle on every choice",
    }
    JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def main(fleet_sizes=FLEET_SIZES, batch_sizes=BATCH_SIZES, header=True,
         emit_json=True):
    if header:  # run.py already printed the combined-stream header
        print("name,us_per_call,derived")
    cells = {}
    for n in fleet_sizes:
        for b in batch_sizes:
            t_scalar, t_scan, t_chunked = run_cell(n, b)
            cells[(n, b)] = (t_scalar, t_scan, t_chunked)
            print(
                f"router_scalar_n{n}_b{b},{t_scalar / b * 1e6:.2f},"
                f"req_per_s={b / t_scalar:.0f}"
            )
            print(
                f"router_scan_n{n}_b{b},{t_scan / b * 1e6:.2f},"
                f"req_per_s={b / t_scan:.0f};speedup={t_scalar / t_scan:.1f}x"
            )
            print(
                f"router_chunked_n{n}_b{b},{t_chunked / b * 1e6:.2f},"
                f"req_per_s={b / t_chunked:.0f}"
                f";speedup_vs_scan={t_scan / t_chunked:.2f}x"
            )
    if emit_json and ACCEPTANCE in cells:
        payload = write_json(cells)
        print(f"wrote {JSON_PATH.name}: {payload['req_per_s']} "
              f"(chunked/scan = {payload['chunked_speedup_over_scan']}x)")


if __name__ == "__main__":
    main()
