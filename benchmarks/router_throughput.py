"""Router throughput: scalar loop vs scan vs chunked vs speculative.

Measures requests/sec for the scalar ``ModelAwareRouter`` (one Python
call per request), ``core.batch_router.route_batch`` with the
single-scan path (the PR 2 baseline), the chunked two-phase commit with
the serial correction scan (``chunk=256, speculative=False`` — the PR 3
A/B baseline), and the SPECULATIVE parallel commit (``chunk=512``,
prefix-committed chunks + suffix replay) across fleet sizes N in
{4, 16, 64} and batch sizes B in {64, 1024, 4096}, verifying on every
cell that all paths agree on all routing choices.

    PYTHONPATH=src python -m benchmarks.router_throughput

prints the CSV sweep (``name,us_per_call,derived``, us per ROUTED
REQUEST) and rewrites ``benchmarks/BENCH_router.json`` — the recorded
perf trajectory: req/s for the scalar / scan / chunked / speculative
paths at the acceptance shape N=64, B=4096 plus the chunked speedup
over the scan path (the PR 3 target, >= 2x) and the speculative speedup
over the serial chunked path (this PR's target, >= 1.5x).

``main(smoke=True)`` (CI) drives every batched path — including the
speculative commit and its replay — over a tiny shape with one timing
repeat, keeping the oracle-equivalence asserts but skipping the JSON:
exercised, not timed.
"""
from __future__ import annotations

import copy
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batch_router as br
from repro.core.catalog import build_catalog
from repro.core.router import EdgeServer, ModelAwareRouter, Request

FLEET_SIZES = (4, 16, 64)
BATCH_SIZES = (64, 1024, 4096)
CHUNK = 256           # two-phase commit chunk at fleet scale
SPEC_CHUNK = 256      # speculative parallel-commit chunk
SPEC_UNROLL = 16      # scan unroll for the cheap speculative recurrence
# BENCH_router.json's chunked req/s as recorded BEFORE the speculative
# commit landed — the acceptance reference for this PR's >= 1.5x claim
# (the serial chunked baseline itself also got faster in the same
# change, so the same-run ratio understates the delta vs that record)
PREV_CHUNKED_REQ_S = 1_107_076
EDGE_ARCHS = ["smollm_135m", "starcoder2_3b", "mamba2_2p7b", "musicgen_medium"]
JSON_PATH = pathlib.Path(__file__).parent / "BENCH_router.json"
ACCEPTANCE = (64, 4096)  # (N, B) cell recorded in BENCH_router.json


def make_fleet(rng, n_servers, catalog, cache_slots=2):
    return [
        EdgeServer(
            name=f"es{i}",
            flops_per_s=float(rng.uniform(5e13, 2e14)),
            cache_slots=cache_slots,
            uplink_bps=1e8,
            backhaul_bps=1e9,
            resident=[(2 * i + j) % len(catalog) for j in range(cache_slots)],
        )
        for i in range(n_servers)
    ]


def make_stream(rng, n_requests, num_models):
    return (
        rng.integers(0, num_models, n_requests),
        rng.uniform(1e5, 1e6, n_requests),
        rng.integers(1, 32, n_requests),
    )


def time_scalar(servers, catalog, models, bits, toks):
    router = ModelAwareRouter(copy.deepcopy(servers), catalog)
    t0 = time.perf_counter()
    choices = [
        router.route(Request(int(m), float(b), int(t)))[0]
        for m, b, t in zip(models, bits, toks)
    ]
    return time.perf_counter() - t0, np.array(choices)


def time_batched(servers, catalog, models, bits, toks, repeats=11,
                 **route_kw):
    params, state = br.fleet_from_servers(servers, catalog)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
    )
    _, out = br.route_batch(params, state, reqs, **route_kw)  # compile
    jax.block_until_ready(out.choice)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        _, out = br.route_batch(params, state, reqs, **route_kw)
        jax.block_until_ready(out.choice)
        best = min(best, time.perf_counter() - t0)
    return best, np.asarray(out.choice)


def run_cell(n_servers, n_requests, seed=0, chunk=CHUNK, repeats=11):
    catalog = build_catalog(EDGE_ARCHS)
    rng = np.random.default_rng(seed)
    servers = make_fleet(rng, n_servers, catalog)
    models, bits, toks = make_stream(rng, n_requests, len(catalog))
    t_scalar, c_scalar = time_scalar(servers, catalog, models, bits, toks)
    t_scan, c_scan = time_batched(servers, catalog, models, bits, toks,
                                  repeats=repeats)
    t_chunked, c_chunked = time_batched(
        servers, catalog, models, bits, toks, repeats=repeats, chunk=chunk,
        speculative=False,
    )
    t_spec, c_spec = time_batched(
        servers, catalog, models, bits, toks, repeats=repeats,
        chunk=min(SPEC_CHUNK, n_requests), unroll=SPEC_UNROLL,
        speculative=True,
    )
    for name, c in (("scan", c_scan), ("chunked", c_chunked),
                    ("speculative", c_spec)):
        assert np.array_equal(c_scalar, c), (
            f"{name} router diverged from scalar oracle at N={n_servers} "
            f"B={n_requests}"
        )
    return t_scalar, t_scan, t_chunked, t_spec


def write_json(cells):
    """Record the perf trajectory (req/s per path) for the acceptance
    cell; cells: {(n, b): (t_scalar, t_scan, t_chunked, t_spec)}."""
    n, b = ACCEPTANCE
    t_scalar, t_scan, t_chunked, t_spec = cells[(n, b)]
    payload = {
        "shape": {"servers": n, "requests": b, "chunk": CHUNK,
                  "spec_chunk": SPEC_CHUNK, "spec_unroll": SPEC_UNROLL},
        "req_per_s": {
            "scalar": round(b / t_scalar),
            "scan": round(b / t_scan),
            "chunked": round(b / t_chunked),
            "chunked_spec": round(b / t_spec),
        },
        "chunked_speedup_over_scan": round(t_scan / t_chunked, 2),
        "spec_speedup_over_chunked": round(t_chunked / t_spec, 2),
        "spec_speedup_over_prev_record": round(
            b / t_spec / PREV_CHUNKED_REQ_S, 2),
        "verified": "all paths agree with the scalar oracle on every choice",
    }
    JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def main(fleet_sizes=FLEET_SIZES, batch_sizes=BATCH_SIZES, header=True,
         emit_json=True, smoke=False):
    if smoke:  # CI: exercise every path on a tiny shape, no timing/JSON
        fleet_sizes, batch_sizes, emit_json = (4,), (96,), False
    if header:  # run.py already printed the combined-stream header
        print("name,us_per_call,derived")
    cells = {}
    for n in fleet_sizes:
        for b in batch_sizes:
            cell = run_cell(n, b, repeats=1 if smoke else 7)
            t_scalar, t_scan, t_chunked, t_spec = cell
            cells[(n, b)] = cell
            print(
                f"router_scalar_n{n}_b{b},{t_scalar / b * 1e6:.2f},"
                f"req_per_s={b / t_scalar:.0f}"
            )
            print(
                f"router_scan_n{n}_b{b},{t_scan / b * 1e6:.2f},"
                f"req_per_s={b / t_scan:.0f};speedup={t_scalar / t_scan:.1f}x"
            )
            print(
                f"router_chunked_n{n}_b{b},{t_chunked / b * 1e6:.2f},"
                f"req_per_s={b / t_chunked:.0f}"
                f";speedup_vs_scan={t_scan / t_chunked:.2f}x"
            )
            print(
                f"router_spec_n{n}_b{b},{t_spec / b * 1e6:.2f},"
                f"req_per_s={b / t_spec:.0f}"
                f";speedup_vs_chunked={t_chunked / t_spec:.2f}x"
            )
    if smoke:
        print("router_throughput_smoke,exercised,paths=scan+chunked+spec")
    if emit_json and ACCEPTANCE in cells:
        payload = write_json(cells)
        print(f"wrote {JSON_PATH.name}: {payload['req_per_s']} "
              f"(chunked/scan = {payload['chunked_speedup_over_scan']}x, "
              f"spec/chunked = {payload['spec_speedup_over_chunked']}x)")


if __name__ == "__main__":
    main()
