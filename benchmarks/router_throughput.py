"""Router throughput: scalar reference loop vs jitted batched dispatch.

Measures requests/sec for the scalar ``ModelAwareRouter`` (one Python
call per request) against ``core.batch_router.route_batch`` (the whole
batch in one jitted ``lax.scan``) across fleet sizes N in {4, 16, 64}
and batch sizes B in {64, 1024, 4096}, verifying on every cell that the
two paths agree on all routing choices.

    PYTHONPATH=src python -m benchmarks.router_throughput

CSV convention: ``name,us_per_call,derived`` (us per ROUTED REQUEST).
"""
from __future__ import annotations

import copy
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batch_router as br
from repro.core.catalog import build_catalog
from repro.core.router import EdgeServer, ModelAwareRouter, Request

FLEET_SIZES = (4, 16, 64)
BATCH_SIZES = (64, 1024, 4096)
EDGE_ARCHS = ["smollm_135m", "starcoder2_3b", "mamba2_2p7b", "musicgen_medium"]


def make_fleet(rng, n_servers, catalog, cache_slots=2):
    return [
        EdgeServer(
            name=f"es{i}",
            flops_per_s=float(rng.uniform(5e13, 2e14)),
            cache_slots=cache_slots,
            uplink_bps=1e8,
            backhaul_bps=1e9,
            resident=[(2 * i + j) % len(catalog) for j in range(cache_slots)],
        )
        for i in range(n_servers)
    ]


def make_stream(rng, n_requests, num_models):
    return (
        rng.integers(0, num_models, n_requests),
        rng.uniform(1e5, 1e6, n_requests),
        rng.integers(1, 32, n_requests),
    )


def time_scalar(servers, catalog, models, bits, toks):
    router = ModelAwareRouter(copy.deepcopy(servers), catalog)
    t0 = time.perf_counter()
    choices = [
        router.route(Request(int(m), float(b), int(t)))[0]
        for m, b, t in zip(models, bits, toks)
    ]
    return time.perf_counter() - t0, np.array(choices)


def time_batched(servers, catalog, models, bits, toks, repeats=3):
    params, state = br.fleet_from_servers(servers, catalog)
    reqs = br.RequestBatch(
        model=jnp.asarray(models, jnp.int32),
        prompt_bits=jnp.asarray(bits, jnp.float32),
        gen_tokens=jnp.asarray(toks, jnp.float32),
    )
    _, out = br.route_batch(params, state, reqs)  # compile
    jax.block_until_ready(out.choice)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        _, out = br.route_batch(params, state, reqs)
        jax.block_until_ready(out.choice)
        best = min(best, time.perf_counter() - t0)
    return best, np.asarray(out.choice)


def run_cell(n_servers, n_requests, seed=0):
    catalog = build_catalog(EDGE_ARCHS)
    rng = np.random.default_rng(seed)
    servers = make_fleet(rng, n_servers, catalog)
    models, bits, toks = make_stream(rng, n_requests, len(catalog))
    t_scalar, c_scalar = time_scalar(servers, catalog, models, bits, toks)
    t_batch, c_batch = time_batched(servers, catalog, models, bits, toks)
    assert np.array_equal(c_scalar, c_batch), (
        f"batched router diverged from scalar oracle at N={n_servers} "
        f"B={n_requests}"
    )
    return t_scalar, t_batch


def main(fleet_sizes=FLEET_SIZES, batch_sizes=BATCH_SIZES, header=True):
    if header:  # run.py already printed the combined-stream header
        print("name,us_per_call,derived")
    for n in fleet_sizes:
        for b in batch_sizes:
            t_scalar, t_batch = run_cell(n, b)
            us_s = t_scalar / b * 1e6
            us_b = t_batch / b * 1e6
            print(
                f"router_scalar_n{n}_b{b},{us_s:.2f},"
                f"req_per_s={b / t_scalar:.0f}"
            )
            print(
                f"router_batched_n{n}_b{b},{us_b:.2f},"
                f"req_per_s={b / t_batch:.0f};speedup={t_scalar / t_batch:.1f}x"
            )


if __name__ == "__main__":
    main()
