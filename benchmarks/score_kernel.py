"""Fused (B, N) routing-score contraction microbenchmark.

Times the eq. 11 score matrix (``core.batch_router.score_matrix``) on
the XLA backend across fleet/batch shapes — the contraction the chunked
``route_batch`` calls once per chunk — and validates the Pallas kernel
against it in interpret mode (interpret emulation is not a meaningful
timing target on CPU; on TPU the kernel path is the one to time).

    PYTHONPATH=src python -m benchmarks.score_kernel

CSV convention: ``name,us_per_call,derived`` (pair-scores per second).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batch_router as br
from repro.core.catalog import build_catalog
from repro.launch.serve import make_multicell_fleet

EDGE_ARCHS = ["smollm_135m", "starcoder2_3b", "mamba2_2p7b", "musicgen_medium"]
SHAPES = ((1024, 16), (4096, 64), (16384, 64))  # (B, N-ish) sweep


def make_case(rng, n_requests, n_cells, servers_per_cell):
    catalog = build_catalog(EDGE_ARCHS)
    fleet = make_multicell_fleet(n_cells, servers_per_cell, catalog)
    params, state = br.fleet_from_servers(fleet, catalog)
    reqs = br.RequestBatch(
        model=jnp.asarray(rng.integers(0, len(catalog), n_requests),
                          jnp.int32),
        prompt_bits=jnp.asarray(rng.uniform(1e5, 1e6, n_requests),
                                jnp.float32),
        gen_tokens=jnp.asarray(rng.integers(1, 32, n_requests), jnp.float32),
        cell=jnp.asarray(rng.integers(0, n_cells, n_requests), jnp.int32),
    )
    return params, state, reqs


def time_backend(params, state, reqs, backend, repeats=5):
    fn = jax.jit(
        lambda p, s, r: br.score_matrix(p, s, r, backend=backend)
    )
    out = fn(params, state, reqs)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(params, state, reqs))
        best = min(best, time.perf_counter() - t0)
    return best


def main(shapes=SHAPES, header=True):
    if header:
        print("name,us_per_call,derived")
    rng = np.random.default_rng(0)

    # interpret-mode kernel validation on a small cell (not a timing)
    params, state, reqs = make_case(rng, 256, 2, 4)
    xla = np.asarray(br.score_matrix(params, state, reqs, backend="xla"))
    pal = np.asarray(
        br.score_matrix(params, state, reqs, backend="pallas-interpret")
    )
    np.testing.assert_allclose(pal, xla, rtol=1e-5)
    assert np.array_equal(np.isinf(pal), np.isinf(xla))
    print("score_kernel_interpret_b256_n9,validated,allclose=1e-5")

    for b, n_total in shapes:
        n_cells = max(1, n_total // 16)
        params, state, reqs = make_case(rng, b, n_cells, 16)
        n = params.flops_per_s.shape[0]
        t = time_backend(params, state, reqs, "xla")
        print(
            f"score_xla_b{b}_n{n},{t * 1e6:.1f},"
            f"pair_scores_per_s={b * n / t:.2e}"
        )


if __name__ == "__main__":
    main()
