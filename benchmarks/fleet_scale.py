"""Fleet-scale mesh routing: req/s vs device count (BENCH_fleet.json).

Routes ONE reconciliation window of B = 256k requests over a C = 64-cell
fleet (16 servers/cell -> N = 1024 edge + 1 cloud column) through
``core.mesh_router.route_batch_sharded`` on a D-device ``cells`` mesh,
for D in {1, 2, 4, 8}, and records requests/sec per device count.

XLA fixes the host device count at first jax init, so the sweep runs in
ONE child process spawned under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; the child
prints a ``FLEET_RESULT {json}`` line per device count, the parent
parses them, prints the CSV rows and rewrites
``benchmarks/BENCH_fleet.json``. (When the current process already
exposes enough devices — a real multi-device host — the sweep runs
inline.)

    PYTHONPATH=src python -m benchmarks.fleet_scale

Honesty note recorded into the JSON: forced host devices share one
CPU's cores, so the D-curve here validates that sharding overhead
(bucketing, reconciliation replay, scatter-back) stays flat — it is not
an accelerator scaling claim. The child also asserts the window is
device-count invariant (choices bitwise across all D).

``main(smoke=True)`` (CI) shrinks to C=8 x 2 servers, B=512, D in
{1, 2}: every path still runs end to end, plus a bitwise parity assert
against the plain single-device ``route_batch`` scan (the smoke fleet
is cloud-free, where the sharded window is exactly the plain scan); no
timing claims, no JSON.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np

NUM_CELLS, PER_CELL = 64, 16
BATCH = 262_144
DEVICES = (1, 2, 4, 8)
CHUNK = 256
REPEATS = 3
SMOKE_CELLS, SMOKE_PER_CELL, SMOKE_BATCH = 8, 2, 512
SMOKE_DEVICES = (1, 2)
EDGE_ARCHS = ["smollm_135m", "starcoder2_3b", "mamba2_2p7b", "musicgen_medium"]
JSON_PATH = pathlib.Path(__file__).parent / "BENCH_fleet.json"
_RESULT_TAG = "FLEET_RESULT "


def build_fleet(rng, n_cells, per_cell, catalog, cloud=True):
    from repro.core.router import EdgeServer
    from repro.launch.serve import make_cloud_server

    fleet = [
        EdgeServer(
            name=f"c{c}-es{i}",
            flops_per_s=float(rng.uniform(5e13, 2e14)),
            cache_slots=2,
            uplink_bps=1e8,
            backhaul_bps=1e9,
            resident=[(2 * (c * per_cell + i) + j) % len(catalog)
                      for j in range(2)],
            cell=c,
        )
        for c in range(n_cells)
        for i in range(per_cell)
    ]
    if cloud:
        fleet.append(make_cloud_server(catalog))
    return fleet


def child_sweep(n_cells, per_cell, batch, devices, chunk, repeats, parity):
    """Run the D-sweep in THIS process (needs >= max(devices) jax devices);
    prints one FLEET_RESULT line per device count."""
    import jax
    import jax.numpy as jnp

    from repro.core import batch_router as br
    from repro.core import mesh_router as mr
    from repro.core.catalog import build_catalog

    assert jax.device_count() >= max(devices), (
        f"need {max(devices)} devices, found {jax.device_count()}; "
        "set XLA_FLAGS=--xla_force_host_platform_device_count=8"
    )
    catalog = build_catalog(EDGE_ARCHS)
    rng = np.random.default_rng(0)
    cloud = not parity  # parity (smoke) runs cloud-free: bitwise vs plain
    fleet = build_fleet(rng, n_cells, per_cell, catalog, cloud=cloud)
    params, state = br.fleet_from_servers(fleet, catalog)
    reqs = br.RequestBatch(
        model=jnp.asarray(rng.integers(0, len(catalog), batch), jnp.int32),
        prompt_bits=jnp.asarray(rng.uniform(1e5, 1e6, batch), jnp.float32),
        gen_tokens=jnp.asarray(rng.integers(1, 32, batch).astype(float),
                               jnp.float32),
        cell=jnp.asarray(rng.integers(0, n_cells, batch), jnp.int32),
    )
    base_choice = None
    for d in devices:
        run = lambda: mr.route_batch_sharded(params, state, reqs,
                                             num_devices=d, chunk=chunk)
        st, out = run()  # compile + warm
        jax.block_until_ready(out.choice)
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            st, out = run()
            jax.block_until_ready(out.choice)
            best = min(best, time.perf_counter() - t0)
        choice = np.asarray(out.choice)
        if base_choice is None:
            base_choice = choice
        else:  # device-count invariance, every sweep
            np.testing.assert_array_equal(choice, base_choice)
        if parity:  # smoke: bitwise vs the plain single-device scan
            st_p, out_p = br.route_batch(params, state, reqs, chunk=chunk)
            np.testing.assert_array_equal(choice, np.asarray(out_p.choice))
            np.testing.assert_array_equal(np.asarray(st.queue_tokens),
                                          np.asarray(st_p.queue_tokens))
        print(_RESULT_TAG + json.dumps({
            "devices": d,
            "cells": n_cells,
            "edge_servers": n_cells * per_cell,
            "batch": batch,
            "chunk": chunk,
            "seconds": best,
            "req_per_s": batch / best,
            "completion_rate": float((choice >= 0).mean()),
        }), flush=True)


def _spawn_child(n_cells, per_cell, batch, devices, chunk, repeats, parity):
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={max(devices)}"
    ).strip()
    repo = pathlib.Path(__file__).resolve().parents[1]
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (str(repo / "src"), str(repo), env.get("PYTHONPATH", ""))
        if p
    )
    cmd = [sys.executable, "-m", "benchmarks.fleet_scale", "--child",
           "--cells", str(n_cells), "--per-cell", str(per_cell),
           "--batch", str(batch), "--chunk", str(chunk),
           "--repeats", str(repeats),
           "--devices", ",".join(map(str, devices))]
    if parity:
        cmd.append("--parity")
    proc = subprocess.run(cmd, cwd=str(repo), env=env, capture_output=True,
                          text=True)
    if proc.returncode != 0:
        raise RuntimeError(
            f"fleet_scale child failed (exit {proc.returncode}):\n"
            f"{(proc.stdout + proc.stderr)[-3000:]}"
        )
    return [json.loads(line[len(_RESULT_TAG):])
            for line in proc.stdout.splitlines()
            if line.startswith(_RESULT_TAG)]


def write_json(rows):
    base = rows[0]["req_per_s"]
    payload = {
        "benchmark": "mesh-sharded fleet routing (core.mesh_router)",
        "shape": {
            "cells": rows[0]["cells"],
            "edge_servers": rows[0]["edge_servers"],
            "cloud_columns": 1,
            "batch_requests_per_window": rows[0]["batch"],
            "chunk": rows[0]["chunk"],
        },
        "req_per_s_by_devices": {
            str(r["devices"]): round(r["req_per_s"]) for r in rows
        },
        "speedup_vs_1_device": {
            str(r["devices"]): round(r["req_per_s"] / base, 3) for r in rows
        },
        "note": ("forced host devices share one CPU's cores: the curve "
                 "bounds sharding overhead, it is not an accelerator "
                 "scaling claim; device-count invariance (bitwise "
                 "choices) is asserted in the same run"),
    }
    JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def main(header=True, smoke=False, emit_json=True):
    if smoke:
        shapes = (SMOKE_CELLS, SMOKE_PER_CELL, SMOKE_BATCH)
        devices, repeats, parity, emit_json = SMOKE_DEVICES, 1, True, False
    else:
        shapes = (NUM_CELLS, PER_CELL, BATCH)
        devices, repeats, parity = DEVICES, REPEATS, False
    n_cells, per_cell, batch = shapes

    import jax

    if jax.device_count() >= max(devices):
        import contextlib
        import io

        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            child_sweep(n_cells, per_cell, batch, devices, chunk=CHUNK,
                        repeats=repeats, parity=parity)
        rows = [json.loads(line[len(_RESULT_TAG):])
                for line in buf.getvalue().splitlines()
                if line.startswith(_RESULT_TAG)]
    else:
        rows = _spawn_child(n_cells, per_cell, batch, devices, chunk=CHUNK,
                            repeats=repeats, parity=parity)

    if header:
        print("name,us_per_call,derived")
    for r in rows:
        us = r["seconds"] / r["batch"] * 1e6
        name = (f"fleet_scale_d{r['devices']}_c{r['cells']}"
                f"n{r['edge_servers']}_b{r['batch']}")
        print(f"{name},{us:.4f},req_per_s={r['req_per_s']:.0f}")
    if smoke:
        print("fleet_scale_smoke,0.0,parity=bitwise_vs_plain_scan")
    if emit_json and rows:
        write_json(rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--child", action="store_true",
                    help="internal: run the sweep in-process (expects the "
                         "forced device count already set)")
    ap.add_argument("--cells", type=int, default=NUM_CELLS)
    ap.add_argument("--per-cell", type=int, default=PER_CELL)
    ap.add_argument("--batch", type=int, default=BATCH)
    ap.add_argument("--chunk", type=int, default=CHUNK)
    ap.add_argument("--repeats", type=int, default=REPEATS)
    ap.add_argument("--devices", default=",".join(map(str, DEVICES)))
    ap.add_argument("--parity", action="store_true")
    args = ap.parse_args()
    if args.child:
        child_sweep(args.cells, args.per_cell, args.batch,
                    tuple(int(d) for d in args.devices.split(",")),
                    args.chunk, args.repeats, args.parity)
    else:
        main(smoke=args.smoke)
