"""Roofline + TPU timing for the fused eq. 11 route-score kernel at
panel sizes where (B, N) exceeds VMEM.

``kernels/route_score.py`` computes the (B, N) scoring contraction in
one VMEM pass, tiled (block_b, block_n). At B = 64k x N = 64 the f32
output panel alone is 16 MB — the whole ~16 MB/core VMEM budget of a
v5e — so the kernel's grid tiling is load-bearing, not a formality:
neither the output nor the (B, Kp) one-hot residency operand can be
resident at once. This benchmark records, per B >= 64k shape:

* the analytic roofline terms (same ``PEAK_FLOPS``/``HBM_BW`` device
  model as ``benchmarks/roofline.py``): HBM bytes and FLOPs for the
  FUSED single pass vs the per-term XLA contraction that materialises
  each (B, N) intermediate (trans / switch-gate / compute / cell mask),
  arithmetic intensity, and the memory-bound time floor each implies;
* on a real TPU (``jax.default_backend() == "tpu"``), wall-clock
  timings of ``score_matrix(backend="pallas")`` against the XLA
  contraction — the measured counterpart of those two floors. On CPU
  the kernel only runs in interpret mode (an emulation, not a timing
  target — see ``score_kernel.py``), so timing columns record null and
  the analytic table is the deliverable.

    PYTHONPATH=src python -m benchmarks.score_roofline

prints the CSV (``name,us_per_call,derived``) and rewrites
``benchmarks/BENCH_score_roofline.json`` next to the other BENCH files.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.roofline import HBM_BW, PEAK_FLOPS
from benchmarks.score_kernel import make_case
from repro.core import batch_router as br

JSON_PATH = pathlib.Path(__file__).parent / "BENCH_score_roofline.json"

SHAPES = ((65536, 64), (131072, 64))  # (B, N): panels past the VMEM line
VMEM_BYTES = 16 * 2**20               # ~16 MB/core (v5e)
BLOCK_B = BLOCK_N = 128               # kernel tile (route_score defaults)
KP = 128                              # catalogue axis, padded to one lane


def analytic_terms(b: int, n: int) -> dict:
    """HBM-traffic and FLOP model of the (B, N) scoring pass, f32."""
    out_panel = b * n * 4
    # fused kernel: two thin feature strips + the residency gemm
    # operands + ONE output write; no (B, N) intermediate ever leaves
    # VMEM (the gate, mask and adds happen in-register per tile)
    fused_bytes = (8 * b * 4 + 8 * n * 4      # request/server strips
                   + b * KP * 4 + KP * n * 4  # one-hot @ resident.T
                   + 4 * (b + n)              # cell columns (int32)
                   + out_panel)
    # per-term XLA contraction: each eq. 5/7/9 term plus the residency
    # gate and the cell mask materialises a (B, N) panel (write + read
    # back for the next elementwise op) before the final sum
    n_panels = 5
    xla_bytes = fused_bytes + 2 * n_panels * out_panel
    flops = 2.0 * b * n * KP + 8.0 * b * n    # gemm + elementwise terms
    return {
        "b": b, "n": n,
        "out_panel_mib": round(out_panel / 2**20, 1),
        "vmem_panels": round(out_panel / VMEM_BYTES, 2),
        "grid": [-(-b // BLOCK_B), -(-n // BLOCK_N)],
        "flops": flops,
        "fused_hbm_bytes": fused_bytes,
        "xla_hbm_bytes": xla_bytes,
        "intensity_fused": round(flops / fused_bytes, 2),
        "intensity_xla": round(flops / xla_bytes, 2),
        # memory floor dominates on both paths: intensity ~ a few
        # FLOP/byte vs the ~240 FLOP/byte v5e ridge point
        "t_fused_us": round(max(fused_bytes / HBM_BW,
                                flops / PEAK_FLOPS) * 1e6, 1),
        "t_xla_us": round(max(xla_bytes / HBM_BW,
                              flops / PEAK_FLOPS) * 1e6, 1),
    }


def time_backend(params, state, reqs, backend, repeats=5):
    fn = jax.jit(lambda p, s, r: br.score_matrix(p, s, r, backend=backend))
    jax.block_until_ready(fn(params, state, reqs))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(params, state, reqs))
        best = min(best, time.perf_counter() - t0)
    return best


def main(shapes=SHAPES, header=True, emit_json=True):
    if header:
        print("name,us_per_call,derived")
    on_tpu = jax.default_backend() == "tpu"
    rng = np.random.default_rng(1)
    records = []
    for b, n_total in shapes:
        terms = analytic_terms(b, n_total)
        print(
            f"score_roofline_b{b}_n{n_total},{terms['t_fused_us']:.1f},"
            f"panel_mib={terms['out_panel_mib']}"
            f";vmem_panels={terms['vmem_panels']}"
            f";t_xla_floor_us={terms['t_xla_us']}"
            f";intensity={terms['intensity_fused']}"
        )
        if on_tpu:
            params, state, reqs = make_case(
                rng, b, max(1, n_total // 16), 16
            )
            t_pal = time_backend(params, state, reqs, "pallas")
            t_xla = time_backend(params, state, reqs, "xla")
            terms["measured_pallas_us"] = round(t_pal * 1e6, 1)
            terms["measured_xla_us"] = round(t_xla * 1e6, 1)
            terms["pallas_speedup"] = round(t_xla / t_pal, 2)
            print(
                f"score_tpu_pallas_b{b}_n{n_total},{t_pal * 1e6:.1f},"
                f"xla_us={t_xla * 1e6:.1f}"
                f";speedup={terms['pallas_speedup']}"
            )
        else:
            terms["measured_pallas_us"] = None
            terms["measured_xla_us"] = None
        records.append(terms)

    if emit_json:
        payload = {
            "device": jax.default_backend(),
            "model": {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW,
                      "vmem_bytes": VMEM_BYTES,
                      "block": [BLOCK_B, BLOCK_N]},
            "shapes": records,
        }
        JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
        print(f"wrote {JSON_PATH.name} ({jax.default_backend()} "
              f"{'measured' if on_tpu else 'analytic-only'})")
    return records


if __name__ == "__main__":
    main()
