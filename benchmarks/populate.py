"""Populate the full paper-benchmark cache (run once, in the background).

Covers every (algorithm × K × M) cell needed by convergence.py,
model_sweep.py and ed_sweep.py. Cells already cached are skipped, so this
is restartable/resumable after interruption (fault tolerance for the
benchmark suite itself).
"""
from __future__ import annotations

import sys
import time

from benchmarks import common


def main(seed: int = 0):
    t0 = time.time()
    cells = []
    for k in (3, 4, 5, 6):  # model sweep at M=10
        for algo in common.ALL_ALGOS:
            cells.append((algo, k, 10))
    for m in (5, 15, 20):  # ED sweep at K=3 (M=10 shared with model sweep)
        for algo in common.ALL_ALGOS:
            cells.append((algo, 3, m))
    print(f"populating {len(cells)} cells", flush=True)
    for i, (algo, k, m) in enumerate(cells):
        common.run_cell(algo, k, m, seed)
        print(f"  [{i + 1}/{len(cells)}] done ({time.time() - t0:.0f}s elapsed)", flush=True)
    print(f"all cells populated in {time.time() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 0)
