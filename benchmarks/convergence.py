"""Paper Fig. 2 — convergence comparison (reward vs. episode).

Trains MADDPG-MATO, MADDPG-NoModel and SADDPG on the reference setting
(K=3 models, M=10 EDs, N=3 ESs) and reports smoothed per-episode rewards.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common


def smooth(x, w=20):
    if len(x) < w:
        return x
    return np.convolve(x, np.ones(w) / w, mode="valid")


def run(k: int = 3, m: int = 10, seed: int = 0) -> dict:
    out = {}
    for algo in common.LEARNED:
        cell = common.run_cell(algo, k, m, seed)
        out[algo] = cell
    return out


def main():
    res = run()
    print("# Fig.2 convergence — smoothed episode reward (sum over agents)")
    print("algo,episode,reward")
    for algo, cell in res.items():
        curve = smooth(np.asarray(cell["episode_reward"]))
        for i in range(0, len(curve), max(1, len(curve) // 25)):
            print(f"{algo},{i},{curve[i]:.2f}")
    print("\n# converged (last-20-episode mean)")
    for algo, cell in res.items():
        tail = np.asarray(cell["episode_reward"])[-20:].mean()
        print(f"{algo},converged_reward,{tail:.2f}")


if __name__ == "__main__":
    main()
