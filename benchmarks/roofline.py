"""Roofline analysis (EXPERIMENTS.md §Roofline) from the dry-run artifacts.

Per (arch x shape) cell on the single-pod 16x16 mesh, derive the three
roofline terms from the per-device compiled module (HLO-parsed with
while-trip multipliers — XLA's cost_analysis counts scan bodies once):

    compute    = device_FLOPs / peak_FLOP/s          (197e12 bf16, v5e)
    memory     = device_HBM_bytes / HBM_bw           (819e9 B/s)
    collective = device_link_bytes / ICI_bw          (50e9 B/s usable)

plus: dominant term, MODEL_FLOPS = 6ND (train) / 2ND (single forward)
with N = active params, the useful-compute ratio, and a one-line lever.

Caveats recorded per cell:
  * HBM bytes from the CPU-backend module OVERCOUNT — XLA CPU upcasts
    bf16 dot operands to f32 mirrors that do not exist on TPU; memory
    terms are therefore upper bounds.
  * collective bytes use ring formulas (all-reduce 2(g-1)/g etc.).
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import SHAPES, get_arch, list_archs

PEAK_FLOPS = 197e12     # bf16 per v5e chip
HBM_BW = 819e9          # B/s
ICI_BW = 50e9           # B/s per link
CHIPS = 256

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_arch(arch)
    sh = SHAPES[shape_name]
    n = cfg.active_param_count()
    if sh["kind"] == "train":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 6.0 * n * tokens          # fwd+bwd
    if sh["kind"] == "prefill":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 2.0 * n * tokens
    return 2.0 * n * sh["global_batch"]  # decode: one token per sequence


def ideal_bytes(arch: str, shape_name: str) -> float:
    """Minimal HBM traffic per step (global, bytes) — the memory floor.

    train:   params read (fwd+bwd) + grad write + Adam moments r/w
             + activation stack write+read (remat keeps one (B,S,d)/layer)
    prefill: params read + KV/state cache write + activations
    decode:  params read once + full cache read + slice write
    """
    cfg = get_arch(arch)
    sh = SHAPES[shape_name]
    n, na = cfg.param_count(), cfg.active_param_count()
    pb = 2.0  # bf16 param bytes
    mb = 2.0 if cfg.moment_dtype == "bfloat16" else 4.0
    tokens = sh["global_batch"] * sh["seq_len"]
    act = tokens * cfg.d_model * cfg.num_layers * 2.0
    if sh["kind"] == "train":
        return n * (3 * pb + 4 * mb) + 2 * act
    if sh["kind"] == "prefill":
        cache = _cache_bytes(cfg, sh)
        return na * pb * max(1, tokens // 8192) + cache + 2 * act
    cache = _cache_bytes(cfg, sh)
    return n * pb + cache  # decode: weights + cache stream


def _cache_bytes(cfg, sh) -> float:
    b = sh["global_batch"]
    s = min(sh["seq_len"], cfg.window) if cfg.window else sh["seq_len"]
    if cfg.family == "ssm":
        return b * cfg.num_layers * (
            cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4.0
            + (cfg.d_inner + 2 * cfg.ssm_state) * (cfg.ssm_conv - 1) * 2.0
        )
    if cfg.family == "hybrid":
        groups = cfg.num_layers // cfg.hybrid_period
        ssm = b * cfg.num_layers * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4.0
        attn = 2.0 * b * groups * s * cfg.num_kv_heads * cfg.head_dim * 2.0
        return ssm + attn
    return 2.0 * b * cfg.num_layers * s * cfg.num_kv_heads * cfg.head_dim * 2.0


def cell_terms(rec: dict) -> dict:
    hlo = rec["hlo"]
    t_compute = hlo["flops"] / PEAK_FLOPS
    t_memory = hlo["hbm_bytes"] / HBM_BW
    t_coll = hlo["collective_bytes"] / ICI_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / CHIPS / max(hlo["flops"], 1.0)
    bound = max(t_compute, t_memory, t_coll)
    # the floor is whichever wall the WORKLOAD inherently hits first:
    # compute (6ND/2ND) or minimal HBM traffic (weights+cache+activations)
    ideal_c = mf / CHIPS / PEAK_FLOPS
    ideal_m = ideal_bytes(rec["arch"], rec["shape"]) / CHIPS / HBM_BW
    ideal = max(ideal_c, ideal_m)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": useful,
        "ideal_s": ideal,
        "roofline_fraction": min(1.0, ideal / max(bound, 1e-30)),
        "peak_gib": rec["memory"]["peak_device_bytes"] / 2**30,
    }


def load_cells(mesh="pod16x16", tag="baseline"):
    out = {}
    for arch in list_archs():
        for shape in SHAPES:
            p = RESULTS / f"{arch}_{shape}_{mesh}_{tag}.json"
            if not p.exists():
                continue
            rec = json.loads(p.read_text())
            if rec["status"] != "ok":
                out[(arch, shape)] = {"status": rec["status"],
                                      "reason": rec.get("reason", "")}
                continue
            terms = cell_terms(rec)
            terms["status"] = "ok"
            out[(arch, shape)] = terms
    return out


LEVERS = {
    "compute": "cut redundant FLOPs (remat policy, drop capacity overprovision)",
    "memory": "fuse / widen arithmetic intensity (kernel fusion, bf16 paths)",
    "collective": "reshard to cut gathers (activation layout, FSDP prefetch)",
}


def main(mesh="pod16x16"):
    cells = load_cells(mesh=mesh)
    print(f"# §Roofline — {mesh}, per-device terms (seconds)")
    print("arch,shape,t_compute,t_memory,t_collective,dominant,"
          "model_flops,useful_ratio,roofline_fraction,peak_GiB,lever")
    for (arch, shape), t in sorted(cells.items()):
        if t["status"] != "ok":
            print(f"{arch},{shape},skipped:{t['reason'][:40]},,,,,,,,")
            continue
        print(
            f"{arch},{shape},{t['t_compute_s']:.4g},{t['t_memory_s']:.4g},"
            f"{t['t_collective_s']:.4g},{t['dominant']},{t['model_flops']:.3g},"
            f"{t['useful_ratio']:.3f},{t['roofline_fraction']:.3f},"
            f"{t['peak_gib']:.1f},{LEVERS[t['dominant']]}"
        )


def main_multipod():
    """Multi-pod sanity: the pod axis must only add gradient traffic."""
    single = load_cells("pod16x16")
    multi = load_cells("pod2x16x16")
    print("# multi-pod delta (collective seconds, 512 vs 256 chips)")
    print("arch,shape,t_coll_single,t_coll_multi,flops_ratio")
    for key in sorted(single):
        s, m = single[key], multi.get(key)
        if not m or s["status"] != "ok" or m["status"] != "ok":
            continue
        fr = m["t_compute_s"] / max(s["t_compute_s"], 1e-12)
        print(f"{key[0]},{key[1]},{s['t_collective_s']:.4g},"
              f"{m['t_collective_s']:.4g},{fr:.3f}")


if __name__ == "__main__":
    import sys

    if len(sys.argv) > 1 and sys.argv[1] == "--multi":
        main_multipod()
    else:
        main()
