"""End-to-end LM training driver example: trains a ~100M-class model for a
few hundred steps through the full production path (sharded params,
deterministic pipeline, atomic checkpoints + auto-resume, straggler
monitor). The loss must visibly fall.

    PYTHONPATH=src python examples/train_lm.py            # reduced, CPU-sized
    PYTHONPATH=src python examples/train_lm.py --full     # real smollm-135m

The same driver trains any of the 10 assigned archs: --arch mixtral_8x7b etc.
"""
import argparse
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.train import train  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt:
        _, losses = train(
            args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
            use_reduced=not args.full, ckpt_dir=ckpt, ckpt_every=100,
        )
    drop = losses[0] - losses[-1]
    print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f} (drop {drop:.3f})")
    assert drop > 0.3, "training failed to reduce loss"
    print("OK: end-to-end training path works")


if __name__ == "__main__":
    main()
