"""Model-aware edge serving demo: the paper's offloading policy routes a
whole batch of generation requests across a 3-server edge fleet caching
real architectures from the assigned pool — one jitted
``core.batch_router`` call with sequential-commit semantics — then each
routed request actually prefills+decodes through the model zoo on the
local device. A second pass scales the same call to a 4-cell fleet with
a cloud-fallback column and a wall-clock (time-based) queue drain.

    PYTHONPATH=src python examples/serve_edge.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import serve  # noqa: E402


def main():
    print("routing 24 requests over 3 edge servers (model-aware greedy)...")
    stats = serve(num_requests=24, n_servers=3, execute=True)
    for k, v in stats.items():
        print(f"  {k}: {v}")
    # model-aware routing should keep most requests on resident models
    assert stats["residency_hit_rate"] > 0.5
    print("OK: model-aware router keeps requests on cached models")

    print("\nrouting 96 requests across a 4-cell fleet (3 servers/cell + "
          "cloud fallback, 50 tok/s time-based drain)...")
    stats = serve(num_requests=96, n_servers=3, execute=False, n_cells=4,
                  drain_rate=50.0, arrival_rate=200.0)
    for k, v in stats.items():
        print(f"  {k}: {v}")
    assert stats["residency_hit_rate"] > 0.5
    assert stats["cloud_fallback_rate"] < 0.5  # cells absorb most traffic
    print("OK: one jitted call routes the whole multi-cell fleet")


if __name__ == "__main__":
    main()
