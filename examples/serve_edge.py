"""Model-aware edge serving demo: the paper's offloading policy routes a
whole batch of generation requests across a 3-server edge fleet caching
real architectures from the assigned pool — one jitted
``core.batch_router`` call with sequential-commit semantics — then each
routed request actually prefills+decodes through the model zoo on the
local device.

    PYTHONPATH=src python examples/serve_edge.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import serve  # noqa: E402


def main():
    print("routing 24 requests over 3 edge servers (model-aware greedy)...")
    stats = serve(num_requests=24, n_servers=3, execute=True)
    for k, v in stats.items():
        print(f"  {k}: {v}")
    # model-aware routing should keep most requests on resident models
    assert stats["residency_hit_rate"] > 0.5
    print("OK: model-aware router keeps requests on cached models")


if __name__ == "__main__":
    main()
