"""Model-aware edge serving demo: the paper's offloading policy routes a
whole batch of generation requests across a 3-server edge fleet caching
real architectures from the assigned pool — one jitted
``core.batch_router`` call with sequential-commit semantics — then each
routed request actually prefills+decodes through the model zoo on the
local device. A second pass scales the same call to a 4-cell fleet with
a cloud-fallback column and a wall-clock (time-based) queue drain; a
third replays the ``flash-crowd`` workload scenario through the
long-horizon simulator (``repro.workloads``) and prints the per-window
time series — watch the queue percentiles spike inside the flash
window.

    PYTHONPATH=src python examples/serve_edge.py
"""
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import batch_router  # noqa: E402
from repro.core.catalog import build_catalog  # noqa: E402
from repro.launch.serve import make_multicell_fleet, serve  # noqa: E402
from repro.workloads import compile_scenario, get_scenario, simulate  # noqa: E402


def main():
    print("routing 24 requests over 3 edge servers (model-aware greedy)...")
    stats = serve(num_requests=24, n_servers=3, execute=True)
    for k, v in stats.items():
        print(f"  {k}: {v}")
    # model-aware routing should keep most requests on resident models
    assert stats["residency_hit_rate"] > 0.5
    print("OK: model-aware router keeps requests on cached models")

    print("\nrouting 96 requests across a 4-cell fleet (3 servers/cell + "
          "cloud fallback, 50 tok/s time-based drain)...")
    stats = serve(num_requests=96, n_servers=3, execute=False, n_cells=4,
                  drain_rate=50.0, arrival_rate=200.0)
    for k, v in stats.items():
        print(f"  {k}: {v}")
    assert stats["residency_hit_rate"] > 0.5
    assert stats["cloud_fallback_rate"] < 0.5  # cells absorb most traffic
    print("OK: one jitted call routes the whole multi-cell fleet")

    print("\nreplaying the flash-crowd scenario (512 requests, 2 cells + "
          "cloud, 3e4 tok/s drain) through the windowed simulator...")
    catalog = build_catalog(
        ["smollm_135m", "starcoder2_3b", "mamba2_2p7b", "musicgen_medium"]
    )
    fleet = make_multicell_fleet(2, 3, catalog, drain_rate=3e4)
    params, state = batch_router.fleet_from_servers(fleet, catalog)
    spec = get_scenario("flash-crowd", num_requests=512)
    reqs = compile_scenario(spec, seed=0, num_models=len(catalog),
                            num_cells=2)
    _, _, series = simulate(params, state, reqs, window_requests=128,
                            cloud_index=len(fleet) - 1)
    print("  window        t[s]  latency  hit  cloud  queue_p90")
    for i in range(len(series.requests)):
        print(f"  {i:6d}  {series.window_start_s[i]:5.1f}-"
              f"{series.window_end_s[i]:4.1f}  "
              f"{series.mean_latency[i]:7.4f}  "
              f"{series.residency_hit_rate[i]:.2f}   "
              f"{series.cloud_fallback_rate[i]:.2f}  "
              f"{series.queue_p90[i]:9.0f}")
    # the spike is visible: queues inside the flash window climb past
    # anything the base-rate windows accumulated
    in_spike = series.window_end_s >= spec.spike_start_s
    peak = series.queue_p90[in_spike].max()
    assert peak > 0.0
    assert peak > np.max(series.queue_p90[~in_spike], initial=0.0)
    print("OK: fleet state carries across windows; the flash window "
          "shows up in the queue percentiles")


if __name__ == "__main__":
    main()
