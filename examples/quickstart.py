"""Quickstart: train MADDPG-MATO on the paper's IIoT offloading environment
and compare it against all four baselines (paper §IV).

    PYTHONPATH=src python examples/quickstart.py [--fast]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

from repro.core import env as env_lib, evaluate, maddpg  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="2-minute demo run")
    ap.add_argument("--eds", type=int, default=10)
    ap.add_argument("--models", type=int, default=3)
    args = ap.parse_args()

    p = env_lib.default_params(num_eds=args.eds, num_models=args.models)
    steps = 1500 if args.fast else 8000
    cfg = maddpg.AlgoConfig(total_steps=steps, batch_size=256 if args.fast else 512,
                            warmup=500 if args.fast else 1500)

    print(f"IIoT env: {args.eds} EDs, 3 ESs, {args.models} AIGC models")
    print(f"training MADDPG-MATO for {steps} env steps ...", flush=True)
    t0 = time.time()
    ts, metrics = maddpg.train_jit(jax.random.key(0), p, cfg)
    jax.block_until_ready(metrics["reward"])
    print(f"trained in {time.time() - t0:.0f}s; "
          f"reward {float(metrics['reward'][:100].mean()):.1f} -> "
          f"{float(metrics['reward'][-100:].mean()):.1f}")

    rows = [("maddpg-mato", evaluate.evaluate_policy(
        jax.random.key(1), "actor", p, cfg=cfg, params=ts.actor))]
    for name in ("random", "greedy"):
        rows.append((name, evaluate.evaluate_policy(jax.random.key(1), name, p)))

    print(f"\n{'algorithm':15s} {'latency(s)':>10s} {'energy(J)':>10s} "
          f"{'completion':>10s} {'switch(s)':>10s}")
    for name, m in rows:
        print(f"{name:15s} {m['latency']:10.3f} {m['energy']:10.3f} "
              f"{m['completion']:10.3f} {m['switch_latency']:10.3f}")


if __name__ == "__main__":
    main()
